"""Fault-tolerant serving: state store, supervised restart, chaos harness.

The ISSUE 9 acceptance properties, pinned as tests:

* the state store round-trips a stream's session state (in memory and
  through the JSONL encoding, including torn-trailing-line recovery and
  TTL reaping);
* an injected dispatcher/collector death under the supervisor recovers
  with zero lost windows and outputs *bit-identical* to a fault-free run
  (async and sync engines, snapshot cadences 1 and 2);
* worker death is a typed ``EngineDead`` (cause + in-flight count),
  distinguishable from ``WindowShed``;
* the crash-loop breaker degrades the knob plan; ``max_restarts`` makes
  the death terminal and fails every pending future;
* metrics and flight events reconcile (restart/replay counters ==
  ``recovery_events`` payloads);
* a SIGKILLed ``repro.launch.serve`` process resumes from its JSONL
  store with a gap-free, bit-identical output ledger (subprocess test).
"""
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

import jax

from repro.core.item_memory import random_item_memory
from repro.runtime.fault import EngineDead, FaultPlan, InjectedFault
from repro.serving.async_engine import AsyncStreamEngine
from repro.serving.state_store import (CACHE_FIELDS, InMemoryStateStore,
                                       JsonlStateStore, StreamSnapshot)
from repro.serving.stream_engine import StreamEngine
from repro.serving.supervisor import ServeSupervisor, recovery_events

from test_multistream import CFG, _make_inputs

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
FLUSH_S = 120


def _snap(sid="cam0", seq=3, seed=0, m=8):
    rng = np.random.default_rng(seed)
    cache = {
        "packed": rng.integers(0, 2**32, (4, 2), dtype=np.uint32),
        "acc": rng.integers(-50, 50, (4, m), dtype=np.int32),
        "acc_tag": rng.integers(0, 4, (4,), dtype=np.int32),
        "out": rng.standard_normal((4, m)).astype(np.float32),
        "topk_key": rng.integers(0, 2**32, (4, 2), dtype=np.uint32),
        "margin": rng.standard_normal((4,)).astype(np.float32),
        "age": rng.integers(0, 9, (4,), dtype=np.int32),
        "valid": rng.integers(0, 2, (4,)).astype(bool),
    }
    return StreamSnapshot(stream_id=sid, window_seq=seq, cache=cache,
                          task_w=rng.standard_normal((m,)).astype(np.float32),
                          meta={"engine": "test"})


# --- state store ------------------------------------------------------------

def test_snapshot_record_roundtrip():
    snap = _snap()
    back = StreamSnapshot.from_record(
        json.loads(json.dumps(snap.to_record())))
    assert back.stream_id == snap.stream_id
    assert back.window_seq == snap.window_seq
    for f in CACHE_FIELDS:
        assert np.array_equal(back.cache[f], snap.cache[f]), f
        assert back.cache[f].dtype == snap.cache[f].dtype, f
    np.testing.assert_array_equal(back.task_w, snap.task_w)
    assert back.meta == snap.meta


def test_snapshot_schema_validation():
    snap = _snap()
    del snap.cache["margin"]
    with pytest.raises(ValueError, match="margin"):
        snap.validate()
    rec = _snap().to_record()
    rec["v"] = 99
    with pytest.raises(ValueError, match="schema"):
        StreamSnapshot.from_record(rec)


def test_inmemory_store_ttl_and_monotonic():
    now = [0.0]
    store = InMemoryStateStore(ttl_s=10.0, clock=lambda: now[0])
    store.put(_snap(seq=5))
    # a stale write (abandoned engine's late delivery) can't regress
    store.put(_snap(seq=4))
    assert store.latest_seq("cam0") == 5
    store.put(_snap(seq=6))
    assert store.latest_seq("cam0") == 6
    now[0] = 5.0
    assert store.get("cam0") is not None
    now[0] = 20.0
    assert store.get("cam0") is None        # TTL-expired: reaped on read
    assert store.latest_seq("cam0") == 0
    assert store.keys() == []


def test_jsonl_store_persistence_torn_line_and_tombstone(tmp_path):
    path = tmp_path / "state.jsonl"
    store = JsonlStateStore(path)
    store.put(_snap(sid="a", seq=1))
    store.put(_snap(sid="a", seq=2, seed=1))
    store.put(_snap(sid="b", seq=7))
    store.close()

    # a fresh process sees latest-record-wins
    store2 = JsonlStateStore(path)
    assert store2.latest_seq("a") == 2
    assert store2.latest_seq("b") == 7
    got = store2.get("a")
    want = _snap(sid="a", seq=2, seed=1)
    for f in CACHE_FIELDS:
        assert np.array_equal(got.cache[f], want.cache[f]), f
    store2.delete("a")                      # appends a tombstone
    store2.close()

    # SIGKILL mid-append: torn trailing line is skipped, prior state wins
    with open(path, "a", encoding="utf-8") as f:
        f.write(json.dumps(_snap(sid="b", seq=9).to_record())[:37])
    store3 = JsonlStateStore(path)
    assert store3.get("a") is None          # tombstone survived reload
    assert store3.latest_seq("b") == 7      # torn seq-9 write discarded
    n = store3.compact()
    assert n == 1
    store3.close()
    lines = [l for l in path.read_text().splitlines() if l.strip()]
    assert len(lines) == 1
    assert json.loads(lines[0])["stream_id"] == "b"


# --- typed EngineDead + chaos plan ------------------------------------------

def test_fault_plan_fires_once_per_thread():
    plan = FaultPlan(at_step=2, thread="collector")
    plan.maybe_fire("dispatcher", 5)        # wrong thread: no-op
    plan.maybe_fire("collector", 1)         # before at_step: no-op
    with pytest.raises(InjectedFault, match="chaos"):
        plan.maybe_fire("collector", 2)
    plan.maybe_fire("collector", 3)         # fired=True: never again
    with pytest.raises(ValueError):
        FaultPlan(at_step=0, thread="scheduler")


def test_engine_dead_is_typed_with_context():
    cfg = CFG
    S, T = 2, 4
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1),
                                           (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    eng = AsyncStreamEngine(cfg, im, n_slots=S, paused=True,
                            fault_plan=FaultPlan(at_step=1,
                                                 thread="dispatcher"))
    futs = []
    for s in range(S):
        eng.admit(f"cam{s}", task_w[s])
        for q, valid, boxes, _qd in steps:
            futs.append(eng.submit(f"cam{s}", q[s], valid[s], boxes[s]))
    eng.start()
    # the message keeps the historical "worker died" phrasing AND the
    # exception is the typed EngineDead carrying crash context
    with pytest.raises(EngineDead, match="worker died") as ei:
        eng.flush(timeout=FLUSH_S)
    assert isinstance(ei.value, RuntimeError)   # backwards compatible
    assert ei.value.thread == "dispatcher"
    assert ei.value.inflight > 0
    assert isinstance(ei.value.cause, InjectedFault)
    eng.close(drain=False)
    # every pending future fails with the same typed death
    failed = [f for f in futs if f.done() and f.exception() is not None]
    assert failed, "worker death must fail in-flight futures"
    assert all(isinstance(f.exception(), EngineDead) for f in failed)


# --- supervised recovery ----------------------------------------------------

def _reference_outputs(cfg, im, task_w, steps, S):
    """Fault-free unsupervised async outputs keyed (stream, seq)."""
    outs = {}
    with AsyncStreamEngine(cfg, im, n_slots=S, paused=True) as eng:
        futs = {}
        for s in range(S):
            eng.admit(f"cam{s}", task_w[s])
            for t, (q, valid, boxes, _qd) in enumerate(steps):
                futs[(s, t)] = eng.submit(f"cam{s}", q[s], valid[s],
                                          boxes[s])
        eng.start()
        eng.flush(timeout=FLUSH_S)
        for k, f in futs.items():
            out, tel = f.result(timeout=10)
            outs[k] = out
    return outs


def _drive_supervised(cfg, im, task_w, steps, S, make_engine, store,
                      **sup_kw):
    sup = ServeSupervisor(make_engine, store, **sup_kw)
    futs = {}
    for s in range(S):
        sup.admit(f"cam{s}", task_w[s])
        for t, (q, valid, boxes, _qd) in enumerate(steps):
            futs[(s, t)] = sup.submit(f"cam{s}", q[s], valid[s], boxes[s])
    if isinstance(sup.engine, AsyncStreamEngine):
        sup.engine.start()
    sup.flush(timeout=FLUSH_S)
    outs = {k: f.result(timeout=10)[0] for k, f in futs.items()}
    return sup, outs


def _assert_outputs_equal(got, want):
    assert set(got) == set(want)
    for k in want:
        assert np.array_equal(np.asarray(got[k].scores),
                              np.asarray(want[k].scores)), k
        assert np.array_equal(np.asarray(got[k].best),
                              np.asarray(want[k].best)), k


@pytest.mark.parametrize("kind", ["dispatcher", "collector"])
@pytest.mark.parametrize("cadence", [1, 2])
def test_async_recovery_bit_identical(kind, cadence):
    cfg = CFG
    S, T = 3, 6
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1),
                                           (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    ref = _reference_outputs(cfg, im, task_w, steps, S)

    from repro.obs import FlightRecorder, MetricsRegistry
    reg, flight = MetricsRegistry(), FlightRecorder(1024)
    store = InMemoryStateStore(metrics=reg)
    fault = FaultPlan(at_step=2, thread=kind)

    def make_engine():
        return AsyncStreamEngine(cfg, im, n_slots=S, paused=True,
                                 store=store, snapshot_every=cadence,
                                 fault_plan=fault)

    sup, outs = _drive_supervised(cfg, im, task_w, steps, S, make_engine,
                                  store, metrics=reg, flight=flight)
    _assert_outputs_equal(outs, ref)
    assert sup.summary()["restarts"] == 1
    assert sup.summary()["pending"] == 0

    # metric/flight reconciliation: the counters and the epoch events
    # describe the same recovery
    snap = reg.snapshot()

    def counter(name):
        return snap[name]["series"][0]["value"]

    evs = recovery_events(flight.records())
    assert [e["event"] for e in evs] == ["engine_crash", "engine_recovered"]
    assert evs[0]["thread"] == kind
    assert counter("torr_engine_restarts_total") == 1 == evs[1]["restarts"]
    assert counter("torr_windows_replayed_total") == evs[1]["replayed"] > 0
    assert counter("torr_state_store_writes_total") > 0
    sup.close(drain=False)


def test_sync_engine_recovery_bit_identical():
    cfg = CFG
    S, T = 3, 6
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1),
                                           (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    ref = _reference_outputs(cfg, im, task_w, steps, S)
    store = InMemoryStateStore()
    fault = FaultPlan(at_step=3, thread="dispatcher")

    def make_engine():
        return StreamEngine(cfg, im, n_slots=S, store=store,
                            snapshot_every=1, fault_plan=fault)

    sup, outs = _drive_supervised(cfg, im, task_w, steps, S, make_engine,
                                  store)
    _assert_outputs_equal(outs, ref)
    assert sup.summary()["restarts"] == 1


def test_retire_deletes_session_state():
    cfg = CFG
    S = 2
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1),
                                           (S, cfg.M)))
    steps = _make_inputs(cfg, S, 2)
    store = InMemoryStateStore()

    def make_engine():
        return AsyncStreamEngine(cfg, im, n_slots=S, paused=True,
                                 store=store, snapshot_every=1)

    sup, _ = _drive_supervised(cfg, im, task_w, steps, S, make_engine,
                               store)
    assert sorted(store.keys()) == ["cam0", "cam1"]
    sup.retire("cam0")
    assert store.keys() == ["cam1"]
    sup.close(drain=False)


def test_crash_loop_breaker_degrades_plan():
    cfg = CFG
    S, T = 2, 5
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1),
                                           (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    ref = _reference_outputs(cfg, im, task_w, steps, S)
    store = InMemoryStateStore()
    built = [0]

    def make_engine():
        # engines 1 and 2 die immediately; engine 3 is healthy — two
        # crashes inside the breaker window trip graceful degradation
        built[0] += 1
        fault = FaultPlan(at_step=0, thread="dispatcher") \
            if built[0] <= 2 else None
        return AsyncStreamEngine(cfg, im, n_slots=S, paused=True,
                                 store=store, snapshot_every=1,
                                 fault_plan=fault)

    sup, outs = _drive_supervised(cfg, im, task_w, steps, S, make_engine,
                                  store, breaker_restarts=2,
                                  backoff_s=0.001)
    assert sup.summary()["restarts"] == 2
    assert sup.summary()["degraded"] is True
    # the surviving engine was latched onto the cheapest ladder plan
    from repro.control.governor import build_ladder
    cheap = build_ladder(cfg)[-1]
    assert sup.engine._plan == cheap
    # degraded plans change banks/precision, not correctness of the
    # cache bookkeeping: every window resolved exactly once
    assert set(outs) == set(ref)
    sup.close(drain=False)


def test_shed_retry_hint_survives_supervised_restart():
    """ISSUE 10 satellite: the ``WindowShed.retry_after_s`` drain-model
    hint must still be attached to sheds raised *after* a supervised
    restart. The DeadlineTracker outlives the engine (the factory closes
    over it), so the replayed windows' sheds carry the same projection a
    fault-free engine would have produced — the gateway forwards it as
    the 429 Retry-After."""
    from repro.serving.deadline import (DeadlinePolicy, DeadlineTracker,
                                        WindowShed)
    cfg = CFG
    S, T = 1, 4
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1),
                                           (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    store = InMemoryStateStore()
    # impossible budget: every admitted window sheds, with a hint from
    # the tracker's drain projection (nonzero step prior so the
    # projection is meaningful before the first completed step)
    tracker = DeadlineTracker(DeadlinePolicy(budget_s=1e-12,
                                             escalate_margin_s=1e-12,
                                             step_init_s=0.004))
    built = [0]

    def make_engine():
        # engine 1 dies at its first dispatch; engine 2 is healthy and
        # REUSES the tracker — recovery must not reset the drain model
        built[0] += 1
        fault = FaultPlan(at_step=0, thread="dispatcher") \
            if built[0] == 1 else None
        return AsyncStreamEngine(cfg, im, n_slots=S, paused=True,
                                 store=store, snapshot_every=1,
                                 tracker=tracker, fault_plan=fault)

    sup = ServeSupervisor(make_engine, store, backoff_s=0.001)
    sup.admit("cam0", task_w[0])
    futs = [sup.submit("cam0", q[0], valid[0], boxes[0])
            for q, valid, boxes, _qd in steps]
    sup.engine.start()
    sup.flush(timeout=FLUSH_S)
    assert sup.summary()["restarts"] == 1
    assert built[0] == 2
    hints = []
    for f in futs:
        exc = f.exception(timeout=10)
        # the shed (not the crash) is what the client sees: replay turned
        # the journaled windows into typed sheds, not EngineDead
        assert isinstance(exc, WindowShed), exc
        hints.append(exc.retry_after_s)
    assert all(h is not None and h > 0 for h in hints), hints
    assert tracker.shed == T
    sup.close(drain=False)


def test_max_restarts_terminal_death_fails_pending():
    cfg = CFG
    S, T = 2, 3
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1),
                                           (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    store = InMemoryStateStore()

    def make_engine():
        return AsyncStreamEngine(cfg, im, n_slots=S, paused=True,
                                 store=store,
                                 fault_plan=FaultPlan(
                                     at_step=0, thread="dispatcher"))

    sup = ServeSupervisor(make_engine, store, max_restarts=2,
                          backoff_s=0.001)
    futs = []
    for s in range(S):
        sup.admit(f"cam{s}", task_w[s])
        for q, valid, boxes, _qd in steps:
            futs.append(sup.submit(f"cam{s}", q[s], valid[s], boxes[s]))
    sup.engine.start()
    with pytest.raises(EngineDead):
        sup.flush(timeout=FLUSH_S)
    assert sup.summary()["restarts"] == sup.max_restarts + 1
    for f in futs:
        assert isinstance(f.exception(timeout=10), EngineDead)
    sup.close(drain=False)


# --- cross-process SIGKILL resume (serve.py end-to-end) ---------------------

def _read_ledger(path):
    recs = {}
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue        # torn trailing write from the SIGKILL
            recs[(r["stream"], r["seq"])] = r
    return recs


def test_serve_sigkill_resume_bit_identical(tmp_path):
    """SIGKILL a supervised serve run mid-wave; the resumed process must
    cover every window exactly, bit-identical to a fault-free ledger."""
    S, T = 2, 10
    env = {**os.environ, "PYTHONPATH": SRC}
    ref = tmp_path / "ref.jsonl"
    out = tmp_path / "out.jsonl"
    store = tmp_path / "state.jsonl"
    base = [sys.executable, "-m", "repro.launch.serve",
            "--torr-streams", str(S), "--torr-frames", str(T), "--async"]

    r = subprocess.run(base + ["--outputs-jsonl", str(ref)], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr
    want = _read_ledger(ref)
    assert len(want) == S * T

    cmd = base + ["--supervise", "--state-store", str(store),
                  "--outputs-jsonl", str(out)]
    p = subprocess.Popen(cmd, env=env, stdout=subprocess.DEVNULL,
                         stderr=subprocess.DEVNULL)
    try:
        deadline = time.monotonic() + 600
        while time.monotonic() < deadline:
            if p.poll() is not None:
                break           # finished before the kill landed: still a
                #                 valid (vacuous-resume) run, asserted below
            if out.exists() and len(_read_ledger(out)) >= 3:
                p.kill()        # SIGKILL: no cleanup, no flush
                p.wait(timeout=60)
                break
            time.sleep(0.05)
        else:
            pytest.fail("serve run neither progressed nor finished")
    finally:
        if p.poll() is None:
            p.kill()
            p.wait(timeout=60)

    covered = _read_ledger(out)
    r2 = subprocess.run(cmd, env=env, capture_output=True, text=True,
                        timeout=600)
    assert r2.returncode == 0, r2.stderr
    if p.returncode != 0:       # the kill landed mid-run
        assert "resumed" in r2.stdout

    merged = _read_ledger(out)
    assert set(merged) == set(want), "lost windows across SIGKILL"
    for k, rec in want.items():
        assert merged[k]["best"] == rec["best"], k
        assert merged[k]["scores_sha256"] == rec["scores_sha256"], k
    # windows the first process had already shipped were not re-served
    # out from under their ledger records — coverage only ever grows
    assert set(covered) <= set(merged)
