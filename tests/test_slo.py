"""RT-SLO burn-rate engine: pure math, multi-window alerting, wiring.

Pins the ISSUE 8 SLO semantics: burn-rate arithmetic, the multi-window
trip condition (both fast AND slow over threshold, never before
``min_events``), level transitions exported as flight events exactly
once per change, the gauge families, the deadline-tracker feed (with an
injected clock so misses are deterministic), and the optional governor
hook — WARN freezes plan recovery, PAGE forces one extra degrade level,
and ``slo=None`` leaves the plan timeline untouched.
"""
import pytest

from repro.control import Governor, GovernorPolicy
from repro.obs.flight import FlightRecorder
from repro.obs.metrics import MetricsRegistry
from repro.obs.slo import (ALERT_NAMES, SLO_OK, SLO_PAGE, SLO_WARN,
                           SLOMonitor, SLOPolicy, burn_rate)
from repro.serving.deadline import DeadlinePolicy, DeadlineTracker

from test_multistream import CFG


# --- pure math ---------------------------------------------------------------


def test_burn_rate_math():
    assert burn_rate(0, 100, 0.01) == 0.0
    assert burn_rate(1, 100, 0.01) == pytest.approx(1.0)   # exactly on budget
    assert burn_rate(10, 100, 0.01) == pytest.approx(10.0)
    assert burn_rate(5, 0, 0.01) == 0.0                    # empty window
    assert burn_rate(64, 64, 0.01) == pytest.approx(100.0)


def test_policy_validation():
    assert SLOPolicy().miss_budget == pytest.approx(0.01)
    with pytest.raises(ValueError):
        SLOPolicy(objective=1.0)
    with pytest.raises(ValueError):
        SLOPolicy(fast_window=8, slow_window=4)
    with pytest.raises(ValueError):
        SLOPolicy(warn_burn=20.0, page_burn=14.4)


# --- multi-window alerting ---------------------------------------------------

# small windows so tests drive full transitions in a few events
POL = SLOPolicy(objective=0.9, fast_window=4, slow_window=8,
                warn_burn=2.0, page_burn=5.0, min_events=4)


def test_min_events_guard():
    mon = SLOMonitor(POL)
    # 3 straight misses: fast burn is 10x budget but the window is too
    # young to alert
    for _ in range(3):
        assert mon.observe(True) == SLO_OK
    assert mon.observe(True) == SLO_PAGE                   # 4th: armed


def test_alert_requires_both_windows():
    # slow window still diluted by hits: fast alone must not page
    mon = SLOMonitor(SLOPolicy(objective=0.9, fast_window=2, slow_window=8,
                               warn_burn=2.0, page_burn=5.0, min_events=2))
    for _ in range(6):
        mon.observe(False)
    mon.observe(True)
    level = mon.observe(True)
    # fast burn = 10, slow burn = 2/8/0.1 = 2.5 -> WARN but not PAGE
    assert level == SLO_WARN
    fast, slow = mon.burn_rates()
    assert fast == pytest.approx(10.0)
    assert slow == pytest.approx(2.5)


def test_levels_recover_as_windows_drain():
    mon = SLOMonitor(POL)
    for _ in range(8):
        mon.observe(True)
    assert mon.alert_level == SLO_PAGE
    for _ in range(8):
        mon.observe(False)
    assert mon.alert_level == SLO_OK
    s = mon.summary()
    assert s["completed"] == 16 and s["missed"] == 8
    assert s["alert"] == "ok" and s["alert_level"] == SLO_OK
    assert s["burn_fast"] == 0.0
    assert ALERT_NAMES[SLO_WARN] == "warn"


def test_flight_events_on_transitions_only():
    fl = FlightRecorder()
    hooks = []
    mon = SLOMonitor(POL, flight=fl,
                     on_alert=lambda lvl, st: hooks.append((lvl, st)))
    for _ in range(8):
        mon.observe(True)       # OK -> PAGE, once
    for _ in range(8):
        mon.observe(False)      # drains through WARN, then back to OK
    recs = [r for r in fl.records() if "slo" in r]
    assert [r["slo"]["level"] for r in recs] == [SLO_PAGE, SLO_WARN, SLO_OK]
    assert recs[0]["slo"]["alert"] == "page"
    assert recs[0]["slo"]["burn_fast"] >= POL.page_burn
    assert mon.alert_transitions == 3
    assert [lvl for lvl, _ in hooks] == [SLO_PAGE, SLO_WARN, SLO_OK]


def test_gauges_exported():
    reg = MetricsRegistry()
    mon = SLOMonitor(POL, metrics=reg)
    for _ in range(8):
        mon.observe(True)
    snap = reg.snapshot()
    burns = {s["labels"]["window"]: s["value"]
             for s in snap["torr_slo_burn_rate"]["series"]}
    assert burns["fast"] == pytest.approx(10.0)
    assert burns["slow"] == pytest.approx(10.0)
    assert snap["torr_slo_alert"]["series"][0]["value"] == SLO_PAGE
    assert snap["torr_slo_miss_budget_remaining"]["series"][0]["value"] == 0.0


# --- deadline tracker feed ---------------------------------------------------


def test_deadline_tracker_feeds_slo():
    clock = iter(range(1000)).__next__
    mon = SLOMonitor(SLOPolicy(objective=0.5, fast_window=4, slow_window=8,
                               warn_burn=1.5, page_burn=1.8, min_events=2))
    tracker = DeadlineTracker(
        DeadlinePolicy(budget_s=0.5, escalate_margin_s=0.2),
        clock=lambda: 0.0, slo=mon)
    # four completions: latency 0.1 (hit), then 1.0 (miss) x3 via `now`
    tracker.complete(arrival_s=-0.1, now=0.0)
    for _ in range(3):
        tracker.complete(arrival_s=-1.0, now=0.0)
    assert mon.completed == 4 and mon.missed == 3
    # miss rate 3/4 over budget 0.5 -> burn 1.5 on both windows: WARN
    assert mon.alert_level == SLO_WARN
    assert tracker.missed == 3
    del clock


# --- governor hook -----------------------------------------------------------


class _FakeSLO:
    def __init__(self):
        self.alert_level = SLO_OK


def test_governor_warn_freezes_recovery():
    slo = _FakeSLO()
    gov = Governor(CFG, GovernorPolicy(budget_s=1.0, recover_hold=1),
                   slo=slo)
    # degrade via PAGE pressure, then hold a WARN: slack alone would
    # recover (generous slack, tiny step EMA), the alert must veto it
    slo.alert_level = SLO_PAGE
    gov.update(slack_s=10.0, step_s=1e-4, backlog=0)
    gov.update(slack_s=10.0, step_s=1e-4, backlog=0)
    lvl = gov.level
    assert lvl >= 1
    slo.alert_level = SLO_WARN
    for _ in range(4):
        gov.update(slack_s=10.0, step_s=1e-4, backlog=0)
        assert gov.level == lvl               # WARN: no widening
    slo.alert_level = SLO_OK
    for _ in range(4):
        gov.update(slack_s=10.0, step_s=1e-4, backlog=0)
    assert gov.level < lvl                    # alert cleared: recovery resumes


def test_governor_page_forces_extra_degrade():
    slo = _FakeSLO()
    gov = Governor(CFG, GovernorPolicy(budget_s=1.0, recover_hold=1),
                   slo=slo)
    slo.alert_level = SLO_PAGE
    # from the full plan with generous slack (slack alone keeps level 0),
    # a page forces one degrade step per update, bounded by the ladder
    gov.update(slack_s=10.0, step_s=1e-4, backlog=0)
    assert gov.level == min(1, len(gov.ladder) - 1)
    for _ in range(len(gov.ladder) + 2):
        gov.update(slack_s=10.0, step_s=1e-4, backlog=0)
    assert gov.level == len(gov.ladder) - 1


def test_governor_without_slo_unchanged():
    """slo=None runs produce the identical plan timeline (bit-match pin)."""
    drives = [(0.01, 0.5, 4), (10.0, 1e-4, 0), (10.0, 1e-4, 0),
              (0.05, 0.2, 2), (10.0, 1e-4, 0)]
    gov_a = Governor(CFG, GovernorPolicy(budget_s=1.0))
    gov_b = Governor(CFG, GovernorPolicy(budget_s=1.0), slo=_FakeSLO())
    for slack, step, backlog in drives:
        gov_a.update(slack_s=slack, step_s=step, backlog=backlog)
        gov_b.update(slack_s=slack, step_s=step, backlog=backlog)
    assert gov_a.plan_log == gov_b.plan_log
