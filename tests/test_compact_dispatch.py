"""Reuse-aware compact-then-compute dispatch (ISSUE 5 tentpole).

The invariants:

  * ``fused="compact"`` is *bit-identical* to the ``fused="off"`` oracle —
    scores, argmax, telemetry AND cache state — across the (banks, planes)
    plan grid, ragged windows, delta-then-full plan switches, reuse mixes
    {0, 0.5, 0.99} and every bucket tier (including tiers the window mix
    overflows: the scalar-cond fallback must be exact, merely slower);
  * driving the bucket ladder across a churny trace compiles a *bounded*
    executable family (<= len(ladder) x len(plan family));
  * ``fused="auto"`` in the engines converges to the compact dispatch on
    reuse-heavy traffic, stays on the hoisted default on full-heavy
    traffic, and never changes a single output bit.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.control import KnobPlan
from repro.core import hdc, pipeline, policy
from repro.core.item_memory import random_item_memory
from repro.core.types import PATH_DELTA, PATH_FULL, TorrConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CFG = TorrConfig(D=1024, B=8, M=32, K=4, N_max=8, delta_budget=128,
                 feat_dim=64)

TELEM_CHECK = ("path", "delta_count", "banks", "rho", "planes", "high_load")


def _plan(banks, planes, cfg=CFG, **kw):
    return KnobPlan(banks=banks, planes=planes, plane_total=cfg.bit_planes,
                    **kw)


def _window(cfg, seed, n_valid=None):
    q_bip = hdc.random_hv(jax.random.PRNGKey(seed), (cfg.N_max, cfg.D))
    valid = np.arange(cfg.N_max) < (
        n_valid if n_valid is not None else cfg.K - 1)
    return q_bip, jnp.asarray(valid), jnp.zeros((cfg.N_max, 4), jnp.float32)


STEP = jax.jit(pipeline.torr_window_step,
               static_argnames=("cfg", "plan", "fused", "bucket_cap"))
MSTEP = jax.jit(pipeline.torr_multi_stream_step,
                static_argnames=("cfg", "serial", "plan", "fused",
                                 "bucket_cap"))


def _run_windows(cfg, im, task_w, plan, fused, bucket_cap=None, n_windows=3,
                 qd_seq=None, seed=11):
    """Warm full -> delta -> bypass sequence through one lowering."""
    state = pipeline.init_state(cfg, task_w)
    q_bip, valid, boxes = _window(cfg, seed=seed)
    outs = []
    for t in range(n_windows):
        q = jax.vmap(hdc.pack_bits)(
            q_bip.at[:, t::131].multiply(-1) if t else q_bip)
        qd = jnp.int32((qd_seq or [0] * n_windows)[t])
        state, out, tel = STEP(state, im, q, valid, boxes, qd, cfg,
                               plan=plan, fused=fused, bucket_cap=bucket_cap)
        outs.append((out, tel))
    return state, outs


def _assert_runs_equal(base, got, ctx=()):
    st0, outs0 = base
    st1, outs1 = got
    for t, ((o0, t0), (o1, t1)) in enumerate(zip(outs0, outs1)):
        assert np.array_equal(np.asarray(o0.scores),
                              np.asarray(o1.scores)), (*ctx, t)
        assert np.array_equal(np.asarray(o0.best),
                              np.asarray(o1.best)), (*ctx, t)
        for f in TELEM_CHECK:
            assert np.array_equal(np.asarray(getattr(t0, f)),
                                  np.asarray(getattr(t1, f))), (*ctx, t, f)
    for a, b in zip(jax.tree_util.tree_leaves(st0.cache),
                    jax.tree_util.tree_leaves(st1.cache)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), ctx


# --- bit-identity over the plan grid x bucket tiers --------------------------

PLANS = [(8, 4), (8, 2), (4, 4), (4, 1), (2, 2), (1, 1)]


@pytest.mark.parametrize("banks,planes", PLANS)
@pytest.mark.parametrize("tier", [1, 4, None])
def test_compact_bit_identical_over_plan_grid(banks, planes, tier):
    """Acceptance: compact == the oracle for every (banks, planes) plan and
    every bucket tier — tier 1 overflows the warm all-full window, proving
    the fallback path, tier None is full capacity."""
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (cfg.M,))
    plan = _plan(banks, planes)
    qd_seq = [0, 0, cfg.q_hi]
    base = _run_windows(cfg, im, task_w, plan, "off", qd_seq=qd_seq)
    got = _run_windows(cfg, im, task_w, plan, "compact", bucket_cap=tier,
                       qd_seq=qd_seq)
    _assert_runs_equal(base, got, (banks, planes, tier))


def test_compact_bucket_cap_latched_via_plan():
    """KnobPlan.bucket_cap is the latched tier when the step gets none."""
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (cfg.M,))
    base = _run_windows(cfg, im, task_w, _plan(8, 4), "off")
    got = _run_windows(cfg, im, task_w, _plan(8, 4, bucket_cap=2), "compact")
    _assert_runs_equal(base, got, ("plan-latched",))
    with pytest.raises(ValueError):
        _plan(8, 4, bucket_cap=0)


def test_compact_ragged_fallback_bit_identical():
    """Ragged M rides the transparent oracle fallback inside the compacted
    kernel dispatch — still bit-identical end to end."""
    cfg = TorrConfig(D=1024, B=8, M=27, K=4, N_max=5, delta_budget=128,
                     feat_dim=64)
    im = random_item_memory(jax.random.PRNGKey(3), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(4), (cfg.M,))
    base = _run_windows(cfg, im, task_w, None, "off", seed=5)
    got = _run_windows(cfg, im, task_w, None, "compact", bucket_cap=2, seed=5)
    _assert_runs_equal(base, got, ("ragged",))


def test_compact_delta_then_full_after_plan_switch():
    """Eq. 6 exactness through the compact path: delta under plan A, then a
    plan switch forces a full re-scan routed through the bucket."""
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (cfg.M,))
    plan_a, plan_b = _plan(8, 4), _plan(4, 2)
    q_bip, valid, boxes = _window(cfg, seed=7)
    nv = int(np.sum(np.asarray(valid)))
    q0 = jax.vmap(hdc.pack_bits)(q_bip)
    q1 = jax.vmap(hdc.pack_bits)(q_bip.at[:, :4].multiply(-1))

    def run(fused, tier):
        st = pipeline.init_state(cfg, task_w)
        st, _, tel0 = STEP(st, im, q0, valid, boxes, jnp.int32(0), cfg,
                           plan=plan_a, fused=fused, bucket_cap=tier)
        assert (np.asarray(tel0.path)[:nv] == PATH_FULL).all()
        st, _, tel_a = STEP(st, im, q1, valid, boxes, jnp.int32(0), cfg,
                            plan=plan_a, fused=fused, bucket_cap=tier)
        assert (np.asarray(tel_a.path)[:nv] == PATH_DELTA).all()
        st, out_b, tel_b = STEP(st, im, q1, valid, boxes, jnp.int32(0), cfg,
                                plan=plan_b, fused=fused, bucket_cap=tier)
        assert (np.asarray(tel_b.path)[:nv] == PATH_FULL).all()
        return st, out_b

    st0, out0 = run("off", None)
    for tier in (2, cfg.N_max):
        st1, out1 = run("compact", tier)
        assert np.array_equal(np.asarray(out0.scores),
                              np.asarray(out1.scores)), tier
        for a, b in zip(jax.tree_util.tree_leaves(st0.cache),
                        jax.tree_util.tree_leaves(st1.cache)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), tier


# --- reuse mixes over the batched step ---------------------------------------

def _mix_steps(cfg, mix, S, T, seed=0):
    """T windows of the shared reuse-mix synthesizer (window 0 warms the
    cache all-full; queue depth pinned high so bypass can fire) — the same
    traces the CI-tracked bench rows measure, so the bit-identity tests
    and the reuse-mix benchmark cannot drift apart."""
    from benchmarks.micro_aligner import _mix_trace

    return _mix_trace(cfg, mix, S, T - 1, seed=seed, numpy=True)


@pytest.mark.parametrize("mix", [0.0, 0.5, 0.99])
@pytest.mark.parametrize("serial", [False, True])
def test_compact_multi_stream_reuse_mixes(mix, serial):
    """Acceptance: the batched compact step == the oracle at reuse mixes
    {0, 0.5, 0.99} in both lowerings, with a tier the mixes over- and
    under-flow."""
    cfg = TorrConfig(D=1024, B=8, M=32, K=8, N_max=8, delta_budget=128,
                     feat_dim=64)
    S, T = 4, 4
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M))
    steps = _mix_steps(cfg, mix, S, T, seed=int(mix * 100))
    tier = policy.bucket_tier(S * cfg.N_max, S * cfg.N_max // 4)

    def run(fused, bucket_cap=None):
        st = pipeline.init_multi_stream_state(cfg, task_w)
        outs = []
        for q, v, b, qd in steps:
            st, out, tel = MSTEP(st, im, jnp.asarray(q), jnp.asarray(v),
                                 jnp.asarray(b), jnp.asarray(qd), cfg,
                                 serial=serial, fused=fused,
                                 bucket_cap=bucket_cap)
            outs.append((out, tel))
        return st, outs

    _assert_runs_equal(run("off"), run("compact", tier), (mix, serial))


def test_compact_multi_stream_heterogeneous_banks():
    """Per-stream Alg. 1 bank choices route through one shared bucket: each
    compacted row must select its own window's bank boundary."""
    cfg = TorrConfig(D=1024, B=8, M=32, K=4, N_max=8, delta_budget=128,
                     feat_dim=64, fps_target=40000.0)
    S = 4
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M))
    q_bip = hdc.random_hv(jax.random.PRNGKey(2), (S, cfg.N_max, cfg.D))
    valid = jnp.asarray(np.arange(cfg.N_max) < 6)[None].repeat(S, 0)
    boxes = jnp.zeros((S, cfg.N_max, 4), jnp.float32)
    qd = jnp.asarray([0, 2, 8, 30], jnp.int32)   # forces banks 8/8/3/1

    def run(fused, tier=None):
        st = pipeline.init_multi_stream_state(cfg, task_w)
        outs = []
        for t in range(3):
            q = jax.vmap(jax.vmap(hdc.pack_bits))(
                q_bip.at[:, :, t::97].multiply(-1) if t else q_bip)
            st, out, tel = MSTEP(st, im, q, valid, boxes, qd, cfg,
                                 fused=fused, bucket_cap=tier)
            outs.append((out, tel))
        return st, outs

    base = run("off")
    banks_seen = np.asarray(base[1][0][1].banks)
    assert len(set(banks_seen.tolist())) > 1, "want heterogeneous banks"
    for tier in (8, None):
        _assert_runs_equal(base, run("compact", tier), (tier,))


# --- bounded executable family -----------------------------------------------

def test_bucket_ladder_helpers():
    assert policy.bucket_ladder(8) == (1, 2, 4, 8)
    assert policy.bucket_ladder(24) == (1, 2, 4, 8, 16, 24)
    assert policy.bucket_tier(24, 5) == 8
    assert policy.bucket_tier(24, 0) == 1
    assert policy.bucket_tier(24, 99) == 24
    with pytest.raises(ValueError):
        policy.bucket_ladder(0)


def test_bucket_ladder_bounded_recompiles():
    """Recompile-count guard: driving every ladder tier x a 2-plan family
    across a churny trace compiles at most len(ladder) x len(plans)
    executables — the bucket capacity is a latched static, not a leak."""
    cfg = TorrConfig(D=1024, B=8, M=32, K=4, N_max=4, delta_budget=128,
                     feat_dim=64)
    S = 2
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M))

    # a locally-defined wrapper gets a private jit cache, so the count
    # below can't be polluted by other tests jitting the same step
    def _mstep(state, im, q, valid, boxes, qd, cfg, plan=None, fused=None,
               bucket_cap=None):
        return pipeline.torr_multi_stream_step(
            state, im, q, valid, boxes, qd, cfg, plan=plan, fused=fused,
            bucket_cap=bucket_cap)

    step = jax.jit(_mstep,
                   static_argnames=("cfg", "plan", "fused", "bucket_cap"))
    ladder = policy.bucket_ladder(S * cfg.N_max)
    plans = (None, _plan(8, 2, cfg))
    st = pipeline.init_multi_stream_state(cfg, task_w)
    rng = np.random.default_rng(0)
    for t in range(3 * len(ladder) * len(plans)):    # churny: revisit tiers
        q = np.asarray(jax.vmap(hdc.pack_bits)(jnp.asarray(
            (rng.integers(0, 2, (S, cfg.N_max, cfg.D)) * 2 - 1)
            .astype(np.int8))))
        st, _, _ = step(st, im, jnp.asarray(q),
                        jnp.ones((S, cfg.N_max), bool),
                        jnp.zeros((S, cfg.N_max, 4), jnp.float32),
                        jnp.zeros((S,), jnp.int32), cfg,
                        plan=plans[t % len(plans)], fused="compact",
                        bucket_cap=ladder[t % len(ladder)])
    assert step._cache_size() <= len(ladder) * len(plans), (
        step._cache_size(), len(ladder), len(plans))


# --- load-aware fused="auto" in the engines ----------------------------------

def _submit_all(eng, task_w, steps, S):
    for s in range(S):
        eng.admit(s, task_w[s])
        for q, v, b, _qd in steps:
            eng.submit(s, q[s], v[s], b[s])


def test_stream_engine_auto_converges_to_compact_on_reuse():
    """Reuse-heavy traffic: the EWMA collapses and the engine dispatches
    the compact lowering with a small ladder tier; outputs stay
    bit-identical to the oracle engine."""
    from repro.serving.stream_engine import StreamEngine

    cfg = TorrConfig(D=1024, B=8, M=32, K=16, N_max=8, delta_budget=128,
                     feat_dim=64)
    S, T = 2, 6
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1),
                                           (S, cfg.M)))
    steps = _mix_steps(cfg, 1.0, S, T)     # identical/drifting windows only

    def run(fused):
        eng = StreamEngine(cfg, im, n_slots=S, fused=fused)
        _submit_all(eng, task_w, steps, S)
        res = eng.drain()
        return eng, res

    eng, res = run("auto")
    assert eng.full_path_ewma < 0.5
    mode, tier, _decide = eng._resolve_fused()
    assert mode == "compact" and tier < S * cfg.N_max
    _, base = run("off")
    for s in range(S):
        for t in range(T):
            assert np.array_equal(np.asarray(res[s][t][0].scores),
                                  np.asarray(base[s][t][0].scores)), (s, t)
            assert np.array_equal(np.asarray(res[s][t][1].path),
                                  np.asarray(base[s][t][1].path)), (s, t)


def test_stream_engine_auto_stays_hoisted_on_full_traffic():
    from repro.serving.stream_engine import StreamEngine

    cfg = TorrConfig(D=1024, B=8, M=32, K=4, N_max=8, delta_budget=128,
                     feat_dim=64)
    S, T = 2, 4
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1),
                                           (S, cfg.M)))
    steps = _mix_steps(cfg, 0.0, S, T)     # fresh queries every window
    eng = StreamEngine(cfg, im, n_slots=S, fused="auto")
    _submit_all(eng, task_w, steps, S)
    eng.drain()
    assert eng.full_path_ewma > 0.5
    mode, tier, _decide = eng._resolve_fused()
    assert mode is None and tier is None   # the hoisted lowering default


def test_async_engine_auto_bit_identical():
    """The async engine's collector-fed EWMA never blocks the dispatcher
    and never changes a bit vs the ungoverned sync engine."""
    from repro.serving.async_engine import AsyncStreamEngine
    from repro.serving.stream_engine import StreamEngine

    cfg = TorrConfig(D=1024, B=8, M=32, K=16, N_max=8, delta_budget=128,
                     feat_dim=64)
    S, T = 2, 5
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1),
                                           (S, cfg.M)))
    steps = _mix_steps(cfg, 0.9, S, T, seed=3)

    sync = StreamEngine(cfg, im, n_slots=S, fused="off")
    _submit_all(sync, task_w, steps, S)
    base = sync.drain()

    with AsyncStreamEngine(cfg, im, n_slots=S, fused="auto",
                           paused=True) as eng:
        futs = {s: [] for s in range(S)}
        for s in range(S):
            eng.admit(s, task_w[s])
            for q, v, b, _qd in steps:
                futs[s].append(eng.submit(s, q[s], v[s], b[s]))
        eng.start()
        eng.flush(timeout=300)
        for s in range(S):
            for t, f in enumerate(futs[s]):
                aout, _atel = f.result(timeout=10)
                assert np.array_equal(aout.scores,
                                      np.asarray(base[s][t][0].scores)), \
                    (s, t)
        assert eng.full_path_ewma < 1.0    # the collector fed the EWMA


def test_compact_four_fake_devices():
    """Acceptance: the compact dispatch is bit-identical to the oracle with
    the stream axis sharded over 4 fake devices (subprocess: the forked
    runtime must see XLA_FLAGS before jax initializes)."""
    code = """
import numpy as np, jax, jax.numpy as jnp
assert jax.device_count() == 4, jax.devices()
from repro.core import pipeline
from repro.core.item_memory import random_item_memory
from repro.core.types import TorrConfig
from repro.runtime import sharding as shd
from repro.serving.async_engine import AsyncStreamEngine
from repro.serving.stream_engine import StreamEngine
from tests.test_compact_dispatch import _mix_steps, _submit_all

cfg = TorrConfig(D=1024, B=8, M=32, K=8, N_max=8, delta_budget=128,
                 feat_dim=64)
S, T = 4, 3
im = random_item_memory(jax.random.PRNGKey(0), cfg)
task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
steps = _mix_steps(cfg, 0.5, S, T)

sync = StreamEngine(cfg, im, n_slots=S, fused="off")
_submit_all(sync, task_w, steps, S)
base = sync.drain()

eng = AsyncStreamEngine(cfg, im, n_slots=S, mesh=shd.stream_mesh(),
                        fused="compact", bucket_cap=8, paused=True)
futs = {s: [] for s in range(S)}
for s in range(S):
    eng.admit(s, task_w[s])
    for q, v, b, _qd in steps:
        futs[s].append(eng.submit(s, q[s], v[s], b[s]))
eng.start()
eng.flush(timeout=300)
for s in range(S):
    for t, f in enumerate(futs[s]):
        aout, atel = f.result(timeout=10)
        assert np.array_equal(aout.scores,
                              np.asarray(base[s][t][0].scores)), (s, t)
        assert np.array_equal(np.asarray(atel.path),
                              np.asarray(base[s][t][1].path)), (s, t)
eng.close()
print("COMPACT-SHARDED-MATCH")
"""
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.path.dirname(SRC),
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "COMPACT-SHARDED-MATCH" in out.stdout
