"""Model zoo: per-arch smoke tests + family invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get, get_smoke
from repro.models import moe as moe_mod
from repro.models import transformer as tf


def _batch_for(cfg, B=2, S=16, key=1):
    rng = np.random.default_rng(key)
    if cfg.family == "audio":
        tk = rng.integers(0, cfg.vocab, (B, S, cfg.n_codebooks))
    else:
        tk = rng.integers(0, cfg.vocab, (B, S))
    batch = {"tokens": jnp.asarray(tk, jnp.int32),
             "labels": jnp.asarray(tk, jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_vision_tokens, cfg.vision_dim)),
            jnp.bfloat16)
    if cfg.family == "moe" and cfg.mtp_depth:
        batch["tokens_next"] = batch["tokens"]
        batch["labels_mtp"] = batch["tokens"]
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_train_and_decode(name):
    cfg = get_smoke(name)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg, S=32)
    loss, metrics = jax.jit(tf.forward_train, static_argnames="cfg")(
        params, batch, cfg)
    assert jnp.isfinite(loss), name
    pb = {k: v for k, v in batch.items()
          if k not in ("labels", "labels_mtp", "tokens_next")}
    cache, logits = jax.jit(tf.prefill, static_argnames="cfg")(params, pb, cfg)
    assert jnp.isfinite(logits).all(), name
    tok = batch["tokens"][:, -1]
    cache2, logits2 = jax.jit(tf.decode_step, static_argnames="cfg")(
        params, cache, tok, cfg)
    assert jnp.isfinite(logits2).all(), name
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_full_config_param_counts(name):
    """Published configs land near their advertised sizes."""
    cfg = get(name)
    n = cfg.param_count()
    expected = {
        "deepseek-v3-671b": (6.0e11, 7.4e11),
        "deepseek-v2-236b": (2.0e11, 2.6e11),
        "gemma-7b": (7.5e9, 9.5e9),   # 8.5B incl. 256k-vocab embeddings
        "phi3-mini-3.8b": (3.3e9, 4.3e9),
        "qwen3-14b": (1.2e10, 1.65e10),
        "deepseek-7b": (6.2e9, 7.6e9),
        "musicgen-large": (1.9e9, 3.7e9),
        "llama-3.2-vision-90b": (8.0e10, 9.5e10),
        "recurrentgemma-2b": (2.2e9, 3.6e9),
        "xlstm-1.3b": (1.0e9, 2.2e9),
    }[name]
    assert expected[0] <= n <= expected[1], (name, n)


def test_moe_activates_fewer_params():
    for name in ("deepseek-v3-671b", "deepseek-v2-236b"):
        cfg = get(name)
        assert cfg.active_param_count() < 0.12 * cfg.param_count()


def test_decode_matches_prefill_continuation():
    """prefill(t[:S]) then decode(t[S]) == prefill(t[:S+1]) logits."""
    cfg = dataclasses.replace(get_smoke("deepseek-7b"), remat_policy="full",
                              dtype="float32")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 17)), jnp.int32)
    cache, _ = tf.prefill(params, {"tokens": toks[:, :16]}, cfg)
    _, logits_dec = tf.decode_step(params, cache, toks[:, 16], cfg)
    _, logits_ref = tf.prefill(params, {"tokens": toks}, cfg)
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_ref),
                               atol=2e-2, rtol=2e-2)


def test_moe_capacity_and_combine():
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="moe", d_model=32, n_experts=4,
                      moe_top_k=2, moe_d_ff=16, capacity_factor=1.5,
                      n_shared_experts=0)
    p = moe_mod.init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    y, aux = moe_mod.moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all() and jnp.isfinite(aux)
    # capacity C rounded up to 8
    assert moe_mod.capacity(16, cfg) == 16  # ceil(16*2/4*1.5=12 -> 16)


def test_gradients_flow_all_archs_sample():
    for name in ("deepseek-v3-671b", "recurrentgemma-2b", "xlstm-1.3b"):
        cfg = get_smoke(name)
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        batch = _batch_for(cfg, S=16)
        g = jax.grad(lambda p: tf.forward_train(p, batch, cfg)[0])(params)
        gn = sum(float(jnp.sum(jnp.abs(l.astype(jnp.float32))))
                 for l in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0, name
