"""Network gateway hardening: every failure mode is a typed client outcome.

The ISSUE 10 acceptance properties, pinned as tests:

* malformed / oversized / truncated frames are 4xx responses, never a
  worker exception — and the gateway keeps serving afterwards;
* slow-loris senders hit the absolute read deadline (408) instead of
  pinning a connection thread;
* per-tenant token buckets and session quotas produce 429s whose
  ``Retry-After``/``X-Retry-After-S`` hints reflect the server's own
  drain model, and the ``torr_gateway_requests_total`` ledger reconciles
  exactly against the client's view;
* shed windows roll the sequence back (a retry of the same seq is a
  fresh, bit-safe submission); deadline-expired windows park and a retry
  of the same seq *collects* the in-flight result;
* a mid-flight client disconnect cancels the wait, marks the seq
  consumed (409 on retry) and shows up in the disconnect counters;
* an engine death behind the gateway is a recovery-aware 503 — the
  gateway itself stays up — and through a supervised engine the whole
  socket round trip survives an injected crash with outputs
  bit-identical to a fault-free run;
* SIGTERM drains gracefully: in-flight requests finish, new ones are
  refused, the process exits 0 (subprocess test).
"""
import http.client
import json
import os
import re
import signal
import socket
import subprocess
import sys
import threading
import time
from concurrent.futures import Future
from types import SimpleNamespace

import numpy as np
import pytest

import jax

from repro.core.item_memory import random_item_memory
from repro.runtime.fault import EngineDead, FaultPlan
from repro.serving import protocol
from repro.serving.async_engine import AsyncStreamEngine
from repro.serving.deadline import WindowShed
from repro.serving.gateway import Gateway, GatewayLimits, SyncDriver
from repro.serving.state_store import InMemoryStateStore
from repro.serving.stream_engine import StreamEngine
from repro.serving.supervisor import ServeSupervisor

from test_multistream import CFG

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# --- plumbing ---------------------------------------------------------------


class _FakeFront:
    """Minimal admit/submit/retire front with scriptable outcomes, so the
    protocol state machine is testable without an engine (no health/heal:
    the gateway must fall back to its defaults)."""

    def __init__(self, n_slots=4):
        self.n_slots = n_slots
        self.slots = {}
        self.futures = []
        self.mode = "ok"            # ok | pending | shed | dead
        self.shed_retry_s = 0.7
        self._n = 0

    def admit(self, sid, task_w, snapshot=None):
        if self.mode == "dead":
            raise EngineDead(RuntimeError("boom"), 0, "disp")
        if len(self.slots) >= self.n_slots:
            raise RuntimeError("no free stream slot")
        self.slots[sid] = slot = len(self.slots)
        return slot

    def retire(self, sid):
        del self.slots[sid]

    def submit(self, sid, q, valid, boxes):
        fut = Future()
        self._n += 1
        if self.mode == "ok":
            wout = SimpleNamespace(
                best=[self._n, 0], scores=np.full((4,), self._n, np.float32))
            fut.set_result((wout, {}))
        elif self.mode == "shed":
            fut.set_exception(WindowShed(sid, 0.01,
                                         retry_after_s=self.shed_retry_s))
        elif self.mode == "dead":
            fut.set_exception(EngineDead(RuntimeError("boom"), 1, "disp"))
        self.futures.append(fut)
        return fut


def _gw(front=None, **limit_kw):
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry()
    limits = GatewayLimits(**limit_kw)
    task_bank = np.eye(4, CFG.M, dtype=np.float32)
    gw = Gateway(front if front is not None else _FakeFront(), CFG,
                 task_bank, limits=limits, metrics=reg, port=0)
    gw.start()
    return gw, reg


def _req(port, method, path, body=None, timeout=15.0, raw=None):
    """One-shot request; returns (status, headers_lowercase, parsed_body)."""
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    try:
        data = raw if raw is not None else (
            json.dumps(body).encode() if body is not None else None)
        conn.request(method, path, body=data,
                     headers={"Content-Type": "application/json"}
                     if data else {})
        r = conn.getresponse()
        rawb = r.read()
        hdr = {k.lower(): v for k, v in r.getheaders()}
        try:
            return r.status, hdr, json.loads(rawb)
        except ValueError:
            return r.status, hdr, rawb
    finally:
        conn.close()


def _open_session(port, tenant="t0", stream="s0", task=0, rt="RT-60"):
    st, _, body = _req(port, "POST", "/v1/session",
                       {"tenant": tenant, "stream": stream, "task": task,
                        "rt": rt})
    assert st == 200, body
    return body


def _frame(seed=0, deadline_ms=None, session="t0/s0", seq=0):
    rng = np.random.default_rng(seed)
    body = {
        "session": session, "seq": seq,
        "q": protocol.encode_array(rng.integers(
            0, 1 << 32, (CFG.N_max, CFG.words), dtype=np.uint32)),
        "valid": protocol.encode_array(np.ones(CFG.N_max, bool)),
        "boxes": protocol.encode_array(
            rng.random((CFG.N_max, 4)).astype(np.float32)),
    }
    if deadline_ms is not None:
        body["deadline_ms"] = deadline_ms
    return body


# --- happy path + idempotency ----------------------------------------------


def test_config_health_and_session_roundtrip():
    gw, _ = _gw()
    try:
        st, _, cfg = _req(gw.port, "GET", "/v1/config")
        assert st == 200
        assert cfg["N_max"] == CFG.N_max and cfg["words"] == CFG.words
        assert cfg["n_tasks"] == 4 and "limits" in cfg

        assert _req(gw.port, "GET", "/healthz")[0] == 200
        st, _, state = _req(gw.port, "GET", "/readyz")
        assert st == 200 and state["ready"] is True

        body = _open_session(gw.port)
        assert body["slot"] == 0 and body["next_seq"] == 0
        # idempotent re-open: same shape -> 200 with existing session
        again = _open_session(gw.port)
        assert again["slot"] == 0
        # conflicting re-open -> 409
        st, _, b = _req(gw.port, "POST", "/v1/session",
                        {"tenant": "t0", "stream": "s0", "task": 1})
        assert st == 409 and b["error"] == "session_exists"

        st, _, first = _req(gw.port, "POST", "/v1/window", _frame(seq=0))
        assert st == 200 and first["seq"] == 0
        assert re.fullmatch(r"[0-9a-f]{64}", first["scores_sha256"])
        # idempotent retry replays the byte-identical cached body
        st, _, replay = _req(gw.port, "POST", "/v1/window", _frame(seq=0))
        assert st == 200 and replay == first
        # out-of-order -> 409 with the expected seq in the detail
        st, _, b = _req(gw.port, "POST", "/v1/window", _frame(seq=5))
        assert st == 409 and b["error"] == "out_of_order"
        assert "expected seq 1" in b["detail"]

        st, _, b = _req(gw.port, "DELETE", "/v1/session/t0/s0")
        assert st == 200 and b["closed"] == "t0/s0"
        st, _, b = _req(gw.port, "POST", "/v1/window", _frame(seq=1))
        assert st == 404 and b["error"] == "no_session"
    finally:
        gw.close()


# --- malformed input battery ------------------------------------------------


def test_malformed_frames_are_400s_and_the_gateway_survives():
    gw, _ = _gw()
    try:
        _open_session(gw.port)
        good = _frame(seq=0)

        bad_json = (b"{nope", b"", b"[1,2]", b'"str"')
        for raw in bad_json:
            st, _, b = _req(gw.port, "POST", "/v1/window", raw=raw)
            assert st == 400, (raw, b)
            assert b["error"] in ("bad_request", "bad_frame")

        # schema violations: every one a 400, named field in the detail
        cases = []
        f = dict(good)
        del f["q"]
        cases.append((f, "q"))
        f = dict(good, seq=True)
        cases.append((f, "seq"))
        f = dict(good, seq=-1)
        cases.append((f, "seq"))
        f = dict(good, session="not-a-session-id")
        cases.append((f, "session"))
        f = dict(good, deadline_ms=0)
        cases.append((f, "deadline_ms"))
        f = dict(good, q=dict(good["q"], dtype="float32"))
        cases.append((f, "q"))
        f = dict(good, q=dict(good["q"], shape=[1, 1]))
        cases.append((f, "q"))
        f = dict(good, q=dict(good["q"],
                              data=good["q"]["data"][:8]))     # truncated
        cases.append((f, "q"))
        f = dict(good, q=dict(good["q"], data="!!!not base64!!!"))
        cases.append((f, "q"))
        nan_boxes = np.full((CFG.N_max, 4), np.nan, np.float32)
        f = dict(good, boxes=protocol.encode_array(nan_boxes))
        cases.append((f, "boxes"))
        for frame, field in cases:
            st, _, b = _req(gw.port, "POST", "/v1/window", frame)
            assert st == 400, (field, st, b)
            assert field in b["detail"] or b["error"] == "bad_frame", b

        # unknown route, wrong method
        assert _req(gw.port, "GET", "/v1/nope")[0] == 404
        assert _req(gw.port, "DELETE", "/v1/window", good)[0] == 405

        # raw garbage on the socket -> 400, connection closed
        s = socket.create_connection(("127.0.0.1", gw.port), timeout=5)
        s.sendall(b"GARBAGE\r\n\r\n")
        resp = s.recv(4096)
        assert b"400" in resp.split(b"\r\n", 1)[0]
        s.close()

        # after the whole battery the same gateway still serves
        st, _, b = _req(gw.port, "POST", "/v1/window", good)
        assert st == 200 and b["seq"] == 0
    finally:
        gw.close()


def test_oversized_body_is_413():
    gw, _ = _gw(max_body_bytes=1024)
    try:
        _open_session(gw.port)
        st, hdr, b = _req(gw.port, "POST", "/v1/window", _frame(seq=0))
        assert st == 413 and b["error"] == "too_large"
        assert hdr.get("connection") == "close"
        # fresh connection still served
        assert _req(gw.port, "GET", "/healthz")[0] == 200
    finally:
        gw.close()


def test_slow_loris_hits_the_read_deadline():
    gw, _ = _gw(read_timeout_s=0.3)
    try:
        t0 = time.monotonic()
        s = socket.create_connection(("127.0.0.1", gw.port), timeout=10)
        s.sendall(b"POST /v1/window HTTP/1.1\r\nContent-")   # ...stall
        resp = s.recv(4096)
        assert b"408" in resp.split(b"\r\n", 1)[0], resp
        assert time.monotonic() - t0 < 5.0
        s.close()

        # truncated body: full headers, half the promised Content-Length
        s = socket.create_connection(("127.0.0.1", gw.port), timeout=10)
        s.sendall(b"POST /v1/window HTTP/1.1\r\n"
                  b"Content-Length: 1000\r\n\r\n" + b"x" * 100)
        resp = s.recv(4096)
        assert b"408" in resp.split(b"\r\n", 1)[0], resp
        s.close()

        assert _req(gw.port, "GET", "/healthz")[0] == 200
    finally:
        gw.close()


# --- overload: rate limits, quotas, shed -----------------------------------


def test_rate_limit_429_with_retry_after_and_ledger_reconcile():
    gw, reg = _gw(rate_per_s=0.5, burst=3)
    try:
        _open_session(gw.port)          # consumes 1 token
        statuses = []
        hints = []
        for seq in (0, 1, 2, 3):
            st, hdr, b = _req(gw.port, "POST", "/v1/window", _frame(seq=seq))
            statuses.append(st)
            if st == 429:
                assert b["error"] == "rate_limit"
                assert int(hdr["retry-after"]) >= 1
                hints.append(float(hdr["x-retry-after-s"]))
                assert b["retry_after_s"] == pytest.approx(hints[-1],
                                                           abs=1e-4)
        assert statuses[:2] == [200, 200] and 429 in statuses
        # integer header rounds the precise hint up, never down
        assert all(h <= int(h + 0.999) for h in hints)

        snap = reg.snapshot()["torr_gateway_requests_total"]["series"]
        server = {(s["labels"]["route"], s["labels"]["status"]): s["value"]
                  for s in snap}
        n200 = sum(1 for s in statuses if s == 200)
        n429 = sum(1 for s in statuses if s == 429)
        assert server[("window", "200")] == n200
        assert server[("window", "429")] == n429
        assert server[("session", "200")] == 1
    finally:
        gw.close()


def test_tenant_quota_and_slot_exhaustion_are_429s():
    gw, _ = _gw(front=_FakeFront(n_slots=2), max_sessions_per_tenant=1)
    try:
        _open_session(gw.port, tenant="a", stream="s0")
        st, _, b = _req(gw.port, "POST", "/v1/session",
                        {"tenant": "a", "stream": "s1", "task": 0})
        assert st == 429 and b["error"] == "tenant_quota"
        _open_session(gw.port, tenant="b", stream="s0")
        st, hdr, b = _req(gw.port, "POST", "/v1/session",
                          {"tenant": "c", "stream": "s0", "task": 0})
        assert st == 429 and b["error"] == "no_slot"
        assert "retry-after" in hdr
    finally:
        gw.close()


def test_shed_rolls_back_seq_and_propagates_the_hint():
    front = _FakeFront()
    gw, reg = _gw(front=front)
    try:
        _open_session(gw.port)
        front.mode = "shed"
        st, hdr, b = _req(gw.port, "POST", "/v1/window", _frame(seq=0))
        assert st == 429 and b["error"] == "shed"
        # the WindowShed.retry_after_s drain-model hint reaches the wire
        assert float(hdr["x-retry-after-s"]) == pytest.approx(0.7)
        assert int(hdr["retry-after"]) == 1
        # shed never advanced engine state: the SAME seq retries fresh
        front.mode = "ok"
        st, _, b = _req(gw.port, "POST", "/v1/window", _frame(seq=0))
        assert st == 200 and b["seq"] == 0
        snap = reg.snapshot()["torr_gateway_rejects_total"]["series"]
        reasons = {s["labels"]["reason"]: s["value"] for s in snap}
        assert reasons.get("shed") == 1
    finally:
        gw.close()


# --- deadlines, parking, disconnects ---------------------------------------


def test_deadline_503_parks_and_the_same_seq_collects():
    front = _FakeFront()
    front.mode = "pending"
    gw, _ = _gw(front=front, request_deadline_s=0.2, poll_interval_s=0.02)
    try:
        _open_session(gw.port)
        t0 = time.monotonic()
        st, hdr, b = _req(gw.port, "POST", "/v1/window",
                          _frame(seq=0, deadline_ms=200))
        assert st == 503 and b["error"] == "deadline"
        assert "retry the same seq" in b["detail"]
        assert 0.15 < time.monotonic() - t0 < 5.0
        # the window is parked in flight; resolve it and collect
        wout = SimpleNamespace(best=[7, 7], scores=np.zeros(4, np.float32))
        front.futures[-1].set_result((wout, {}))
        st, _, b = _req(gw.port, "POST", "/v1/window",
                        _frame(seq=0, deadline_ms=200))
        assert st == 200 and b["seq"] == 0 and b["best"] == [7, 7]
        # and the cached-dedupe path still works after collection
        st, _, b2 = _req(gw.port, "POST", "/v1/window",
                         _frame(seq=0, deadline_ms=200))
        assert st == 200 and b2 == b
    finally:
        gw.close()


def test_mid_flight_disconnect_cancels_and_consumes_the_seq():
    front = _FakeFront()
    front.mode = "pending"
    gw, reg = _gw(front=front, request_deadline_s=30.0,
                  poll_interval_s=0.02)
    try:
        _open_session(gw.port)
        frame = json.dumps(_frame(seq=0)).encode()
        s = socket.create_connection(("127.0.0.1", gw.port), timeout=10)
        s.sendall(b"POST /v1/window HTTP/1.1\r\n"
                  b"Content-Type: application/json\r\n"
                  + f"Content-Length: {len(frame)}\r\n\r\n".encode()
                  + frame)
        # wait until the gateway is blocked on the (never-resolving)
        # future, then vanish
        for _ in range(200):
            if front.futures:
                break
            time.sleep(0.01)
        assert front.futures
        time.sleep(0.1)
        s.close()
        # liveness polling notices, cancels the wait, counts the drop
        for _ in range(300):
            if front.futures[0].cancelled():
                break
            time.sleep(0.01)
        assert front.futures[0].cancelled()
        snap = reg.snapshot()
        assert snap["torr_gateway_disconnects_total"]["series"][0][
            "value"] >= 1
        reasons = {x["labels"]["reason"]: x["value"]
                   for x in snap["torr_gateway_rejects_total"]["series"]}
        assert reasons.get("disconnect", 0) >= 1
        # the engine saw the window once: the seq stays consumed
        st, _, b = _req(gw.port, "POST", "/v1/window", _frame(seq=0))
        assert st == 409 and b["error"] == "seq_consumed"
        assert "resume at seq 1" in b["detail"]
        # the stream resumes cleanly at the next seq
        front.mode = "ok"
        st, _, b = _req(gw.port, "POST", "/v1/window", _frame(seq=1))
        assert st == 200 and b["seq"] == 1
    finally:
        gw.close()


def test_engine_dead_is_a_503_and_the_gateway_stays_up():
    front = _FakeFront()
    gw, _ = _gw(front=front)
    try:
        _open_session(gw.port)
        front.mode = "dead"
        st, _, b = _req(gw.port, "POST", "/v1/window", _frame(seq=0))
        # no heal() on this front: the death is terminal, not recovering
        assert st == 503 and b["error"] == "engine_dead"
        assert _req(gw.port, "GET", "/healthz")[0] == 200
        st, _, b = _req(gw.port, "POST", "/v1/session",
                        {"tenant": "t9", "stream": "s0", "task": 0})
        assert st == 503 and b["error"] == "engine_dead"
    finally:
        gw.close()


def test_drain_refuses_new_work_and_reports_not_ready():
    gw, reg = _gw()
    try:
        _open_session(gw.port)
        assert gw.drain(timeout=5.0) is True
        assert gw.summary()["draining"] is True
        # new connections get a typed 503 (accept thread winding down)
        # or a TCP refusal (listener gone) — never a hang or a 200
        try:
            st, _, b = _req(gw.port, "GET", "/readyz", timeout=5)
            assert st == 503 and b["error"] == "draining", (st, b)
        except OSError:
            pass
        snap = reg.snapshot()
        assert snap["torr_gateway_draining"]["series"][0]["value"] == 1
    finally:
        gw.close()


# --- real engines behind the gateway ---------------------------------------


def test_sync_driver_front_serves_windows():
    im = random_item_memory(jax.random.PRNGKey(0), CFG)
    eng = StreamEngine(CFG, im, n_slots=2)
    front = SyncDriver(eng)
    gw, _ = _gw(front=front, request_deadline_s=60.0)
    try:
        _open_session(gw.port)
        shas = []
        for seq in range(3):
            st, _, b = _req(gw.port, "POST", "/v1/window",
                            _frame(seed=seq, seq=seq), timeout=120)
            assert st == 200 and b["seq"] == seq
            shas.append(b["scores_sha256"])
        assert len(set(shas)) >= 1     # served, digests well-formed
        st, _, b = _req(gw.port, "DELETE", "/v1/session/t0/s0")
        assert st == 200
    finally:
        gw.close()
        front.close()


def _drive_through_gateway(port, n_windows, deadline_ms=None):
    """Serial client with bounded Retry-After-honouring retries; returns
    (bodies, statuses_seen)."""
    bodies, seen = [], []
    seq = 0
    for w in range(n_windows):
        frame = _frame(seed=1000 + w, seq=seq, deadline_ms=deadline_ms)
        for _attempt in range(400):
            st, hdr, b = _req(port, "POST", "/v1/window", frame, timeout=120)
            seen.append(st)
            if st == 200:
                bodies.append(b)
                seq += 1
                break
            assert st in (429, 503), (st, b)
            time.sleep(min(float(hdr.get("x-retry-after-s", 0.05)), 0.5))
        else:
            raise AssertionError(f"window {w} never served: {seen[-5:]}")
    return bodies, seen


def test_gateway_chaos_recovery_bit_identical():
    """An injected dispatcher death under the supervisor, seen from the
    socket: the client gets recovery-aware 503s, retries the same seq,
    and the final output stream is bit-identical to a fault-free run."""
    im = random_item_memory(jax.random.PRNGKey(0), CFG)
    n_windows = 8

    def _run(fault, backoff_s):
        store = InMemoryStateStore()

        def make_engine():
            return AsyncStreamEngine(CFG, im, n_slots=2, paused=True,
                                     store=store, snapshot_every=1,
                                     fault_plan=fault)

        sup = ServeSupervisor(make_engine, store, backoff_s=backoff_s)
        sup.engine.warmup()
        sup.engine.start()
        gw, _ = _gw(front=sup, request_deadline_s=0.25,
                    poll_interval_s=0.02)
        try:
            _open_session(gw.port)
            bodies, seen = _drive_through_gateway(gw.port, n_windows,
                                                  deadline_ms=250)
        finally:
            gw.drain(timeout=5.0)
            gw.close()
            sup.close(drain=False)
        return bodies, seen, sup.summary()

    ref, _seen_ref, _ = _run(fault=None, backoff_s=0.02)

    fault = FaultPlan(at_step=3, thread="dispatcher")
    got, seen, summary = _run(fault=fault, backoff_s=0.6)
    assert summary["restarts"] == 1, summary
    # the crash was client-visible as a typed retryable outcome...
    assert any(s == 503 for s in seen), seen
    # ...and zero accepted windows were lost: every seq served exactly
    # once, bit-identical to the fault-free reference
    assert [b["seq"] for b in got] == list(range(n_windows))
    assert [b["scores_sha256"] for b in got] == \
        [b["scores_sha256"] for b in ref]
    assert [b["best"] for b in got] == [b["best"] for b in ref]


@pytest.mark.slow
def test_sigterm_drains_and_exits_zero(tmp_path):
    """SIGTERM mid-traffic: the server drains in-flight work, refuses new
    requests and exits 0 (the orchestrator-facing contract)."""
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""),
               PYTHONUNBUFFERED="1", JAX_PLATFORMS="cpu")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--gateway-port", "0",
         "--supervise", "--torr-slots", "2"],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    port = None
    try:
        t0 = time.time()
        while time.time() - t0 < 300:
            line = proc.stdout.readline()
            m = re.search(r"listening on http://127\.0\.0\.1:(\d+)", line)
            if m:
                port = int(m.group(1))
                break
        assert port, "no gateway handshake"
        _open_session(port)
        # the subprocess serves its own (bigger) config: size the frame
        # from /v1/config, not the in-process test CFG
        st, _, cfg = _req(port, "GET", "/v1/config")
        assert st == 200, cfg
        rng = np.random.default_rng(0)
        frame = {
            "session": "t0/s0", "seq": 0,
            "q": protocol.encode_array(rng.integers(
                0, 1 << 32, (cfg["N_max"], cfg["words"]), dtype=np.uint32)),
            "valid": protocol.encode_array(np.ones(cfg["N_max"], bool)),
            "boxes": protocol.encode_array(
                rng.random((cfg["N_max"], 4)).astype(np.float32)),
        }
        st, _, b = _req(port, "POST", "/v1/window", frame, timeout=120)
        assert st == 200, b
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=120)
        assert proc.returncode == 0, out[-3000:]
        assert "drained=True" in out and "exit 0" in out
    finally:
        if proc.poll() is None:
            proc.kill()
