"""RT-deadline admission control: pure decision table + tracker accounting.

The decision function is pure in (wait, backlog, step_ema, policy), so the
miss/shed/escalate semantics are table-driven; the tracker's clock is
injected so completion/miss accounting is deterministic.
"""
import numpy as np
import pytest

from repro.configs import rt_budget_s
from repro.perf.cycle_model import latency_summary
from repro.serving.deadline import (Decision, DeadlinePolicy, DeadlineTracker,
                                    WindowShed, decide, policy_for)

MS = 1e-3
# RT-60-shaped test policy: ~16.7 ms budget, 8 ms escalate margin
POL = DeadlinePolicy(budget_s=16 * MS, escalate_margin_s=8 * MS)

# (wait_ms, backlog, step_ms, expected) — the admission decision table
DECISION_TABLE = [
    # comfortably early, empty queue -> admit
    (0.0, 0, 1.0, Decision.ADMIT),
    (5.0, 0, 5.0, Decision.ADMIT),
    # exactly on budget (lateness == 0) -> still admitted
    (15.0, 0, 1.0, Decision.ADMIT),
    # just past the deadline but within the escalate margin -> escalate
    (16.0, 0, 1.0, Decision.ESCALATE),
    (20.0, 0, 2.0, Decision.ESCALATE),
    # on time itself, but the backlog behind projects over budget -> escalate
    (0.0, 4, 5.0, Decision.ESCALATE),     # 0 + 5*5 = 25 > 16
    (0.0, 2, 5.0, Decision.ADMIT),        # 0 + 3*5 = 15 <= 16
    # hopelessly late (lateness > margin) -> shed
    (30.0, 0, 1.0, Decision.SHED),
    (10.0, 0, 20.0, Decision.SHED),
    # zero step estimate (no step observed yet): only wait counts
    (17.0, 0, 0.0, Decision.ESCALATE),
    (40.0, 0, 0.0, Decision.SHED),
]


@pytest.mark.parametrize("wait_ms,backlog,step_ms,expected", DECISION_TABLE)
def test_decision_table(wait_ms, backlog, step_ms, expected):
    got = decide(wait_ms * MS, backlog, step_ms * MS, POL)
    assert got == expected


def test_shed_disabled_escalates_instead():
    pol = DeadlinePolicy(budget_s=16 * MS, escalate_margin_s=8 * MS,
                         allow_shed=False)
    assert decide(30 * MS, 0, 1 * MS, pol) == Decision.ESCALATE


def test_policy_for_rt_operating_points():
    assert policy_for("RT-60").budget_s == pytest.approx(1 / 60)
    assert policy_for("RT-30").budget_s == pytest.approx(1 / 30)
    assert policy_for("RT-30").escalate_margin_s == pytest.approx(0.5 / 30)
    assert policy_for("RT-60", allow_shed=False).allow_shed is False
    with pytest.raises(ValueError):
        rt_budget_s("RT-15")


def test_tracker_step_ema_and_decisions():
    t = DeadlineTracker(POL, clock=lambda: 0.0)
    assert t.step_ema_s == 0.0
    t.observe_step(10 * MS)            # first sample seeds the EMA
    assert t.step_ema_s == pytest.approx(10 * MS)
    t.observe_step(20 * MS)            # EMA with alpha=0.25
    assert t.step_ema_s == pytest.approx(0.75 * 10 * MS + 0.25 * 20 * MS)

    # head arrived at -30ms -> wait 30ms, step ~12.5ms -> hopeless -> shed
    assert t.decide_head(-30 * MS, 0, now=0.0) == Decision.SHED
    assert t.shed == 1
    # fresh head, small backlog -> admit
    assert t.decide_head(0.0, 0, now=0.0) == Decision.ADMIT
    # fresh head, deep backlog -> escalate
    assert t.decide_head(0.0, 5, now=0.0) == Decision.ESCALATE
    assert t.escalated == 1


def test_tracker_completion_and_miss_accounting():
    t = DeadlineTracker(POL, clock=lambda: 0.0)
    lats_ms = [5, 10, 12, 18, 40]      # 2 of 5 over the 16 ms budget
    for lat in lats_ms:
        t.complete(arrival_s=-lat * MS, now=0.0)
    assert t.completed == 5
    assert t.missed == 2
    s = t.summary()
    assert s["miss_count"] == 2
    assert s["miss_rate"] == pytest.approx(2 / 5)
    assert s["median_ms"] == pytest.approx(12.0)
    assert s["n_windows"] == 5
    # same vocabulary as the cycle model's envelope summaries
    sim_keys = set(latency_summary(np.array([1.0]), 1.0))
    assert sim_keys <= set(s)


def test_latency_summary_empty_and_jitter():
    s = latency_summary(np.array([]), 1 / 60)
    assert s["n_windows"] == 0 and s["miss_rate"] == 0.0
    lat = np.array([10.0, 10.0, 10.0, 10.0, 30.0]) * MS
    s = latency_summary(lat, 16 * MS)
    assert s["jitter_ms"] == pytest.approx(s["p95_ms"] - s["median_ms"])
    assert s["miss_rate"] == pytest.approx(1 / 5)


def test_window_shed_message_carries_context():
    e = WindowShed("cam3", 0.0123)
    assert "cam3" in str(e) and "12.30 ms" in str(e)
    assert e.lateness_s == pytest.approx(0.0123)


def test_window_shed_retry_after_hint_in_message():
    e = WindowShed("cam1", 0.020, retry_after_s=0.0335)
    assert e.retry_after_s == pytest.approx(0.0335)
    assert "retry after 33.50 ms" in str(e)
    assert WindowShed("cam1", 0.020).retry_after_s is None


@pytest.mark.parametrize("backlog,step_ms", [
    (0, 1.0), (0, 20.0), (2, 5.0), (4, 5.0), (8, 3.0), (30, 2.0),
    (1, 16.0), (0, 16.0),
])
def test_retry_after_backoff_readmits(backlog, step_ms):
    """The shed hint is exactly what makes the pure decision table ADMIT
    again under its own drain model: after backing off by the hint, the
    windows the backlog drained in the meantime bring a fresh arrival's
    completion projection back inside the budget."""
    import math

    from repro.serving.deadline import retry_after_s

    step = step_ms * MS
    hint = retry_after_s(backlog, step, POL)
    assert hint >= 0.0
    if hint == 0.0:
        # nothing to wait for: the table admits a fresh window right now
        assert decide(0.0, backlog, step, POL) == Decision.ADMIT
        return
    # without backing off, the fresh window would NOT be admitted
    assert decide(0.0, backlog, step, POL) != Decision.ADMIT
    if step > POL.budget_s:
        # a single window already blows the budget: no amount of drain
        # re-admits, and the hint reflects that residual overrun
        assert hint >= step - POL.budget_s - 1e-12
        return
    # after the hint, the backlog has drained hint/step windows (the
    # decision table's own one-window-per-step projection)
    drained = math.ceil(hint / step - 1e-9)
    assert 0 <= drained <= backlog
    assert decide(0.0, backlog - drained, step, POL) == Decision.ADMIT


def test_tracker_retry_after_hint_tracks_step_ema():
    t = [0.0]
    tr = DeadlineTracker(POL, clock=lambda: t[0])
    from repro.serving.deadline import retry_after_s
    assert tr.retry_after_hint(4) == pytest.approx(
        retry_after_s(4, POL.step_init_s, POL))
    tr.observe_step(8 * MS)     # step EMA moves; the hint moves with it
    assert tr.retry_after_hint(4) == pytest.approx(
        retry_after_s(4, tr._step_s, POL))
    assert tr.retry_after_hint(4) > 0.0
