"""Event aggregation (Eq. 1), spiking encoder, contrastive bridge (Eq. 2-3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bridge, encoder, events


def test_eq1_normalization():
    ev = events.EventBatch(
        x=jnp.array([1, 1, 2, 0]), y=jnp.array([1, 1, 3, 0]),
        t=jnp.array([0.001, 0.002, 0.003, 0.0]),
        p=jnp.array([1, 1, 0, 0]), count=jnp.int32(3))
    fr = events.eq1_frame(ev, 8, 8)
    assert float(jnp.max(jnp.abs(fr))) == pytest.approx(1.0, abs=1e-4)
    assert float(fr[1, 1]) > 0     # two positive events
    assert float(fr[3, 2]) < 0     # one negative event


def test_aggregate_window_counts_and_padding():
    ev = events.EventBatch(
        x=jnp.array([1, 2, 3, 7]), y=jnp.array([1, 2, 3, 7]),
        t=jnp.array([0.0, 0.001, 0.002, 0.003]),
        p=jnp.array([1, 0, 1, 1]), count=jnp.int32(3))   # 4th is padding
    vol = events.aggregate_window(ev, 0.004, 4, 8, 8)
    assert float(vol.sum()) == 3.0
    assert vol.shape == (4, 8, 8, 2)


def test_encoder_surrogate_gradients():
    ecfg = encoder.EncoderConfig(c1=4, c2=8, feat_dim=16)
    p = encoder.init_encoder(jax.random.PRNGKey(0), ecfg)
    vol = jax.random.uniform(jax.random.PRNGKey(1), (4, 16, 16, 2))
    g = jax.grad(lambda p: jnp.sum(encoder.encode(p, vol, ecfg) ** 2))(p)
    assert float(jnp.linalg.norm(g.conv1)) > 0
    assert float(jnp.linalg.norm(g.conv2)) > 0


def test_bridge_losses_finite_and_aligned_beats_random():
    key = jax.random.PRNGKey(0)
    emb = jax.random.normal(key, (8, 32))
    tb = jax.random.normal(jax.random.PRNGKey(1), (10, 32))
    labels = jnp.arange(8) % 10
    # perfectly aligned pairs -> lower loss than mismatched
    l_same, _ = bridge.bridge_loss(emb, emb, tb, labels)
    shuffled = emb[::-1]
    l_diff, _ = bridge.bridge_loss(emb, shuffled, tb, labels)
    assert float(l_same) < float(l_diff)


def test_bridge_short_training_improves():
    ecfg = encoder.EncoderConfig(c1=4, c2=8, feat_dim=32)
    params = encoder.init_encoder(jax.random.PRNGKey(0), ecfg)
    f_img = bridge.make_frozen_proxy(jax.random.PRNGKey(1), 4, 32)
    tb = jax.random.normal(jax.random.PRNGKey(2), (4, 32))
    rng = np.random.default_rng(0)
    centers = [(4, 4), (4, 12), (12, 4), (12, 12)]

    def batch(step):
        r = np.random.default_rng(step)
        labels = r.integers(0, 4, 8)
        vols = np.zeros((8, 2, 16, 16, 2), np.float32)
        for i, c in enumerate(labels):
            cy, cx = centers[c]
            ys = np.clip(r.normal(cy, 1.2, 40).astype(int), 0, 15)
            xs = np.clip(r.normal(cx, 1.2, 40).astype(int), 0, 15)
            np.add.at(vols[i], (r.integers(0, 2, 40), ys, xs,
                                r.integers(0, 2, 40)), 1.0)
        return (jnp.asarray(vols),
                f_img(jax.nn.one_hot(jnp.asarray(labels), 4)),
                jnp.asarray(labels))

    def loss_fn(p, v, ie, l):
        ev = encoder.encode_batch(p, v, ecfg)
        return bridge.bridge_loss(ie, ev, tb, l)

    losses = []
    lr = 5e-3
    for s in range(30):
        v, ie, l = batch(s)
        (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, v, ie, l)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
