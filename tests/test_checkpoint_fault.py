"""Checkpointing + fault tolerance + straggler watchdog."""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.fault import (StragglerWatchdog, SupervisorConfig,
                                 TrainSupervisor)


def test_roundtrip_and_keep_last():
    d = tempfile.mkdtemp()
    try:
        cm = CheckpointManager(d, keep_last=2)
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "b": {"c": jnp.ones((5,), jnp.int32)}}
        for s in (10, 20, 30):
            cm.save(s, tree)
        assert cm.all_steps() == [20, 30]
        restored, step = cm.restore(tree)
        assert step == 30
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["b"]["c"].dtype == jnp.int32
    finally:
        shutil.rmtree(d)


def test_resave_same_step_is_idempotent():
    d = tempfile.mkdtemp()
    try:
        cm = CheckpointManager(d, keep_last=3)
        cm.save(5, {"x": jnp.zeros(3)})
        cm.save(5, {"x": jnp.ones(3)})
        restored, _ = cm.restore({"x": jnp.zeros(3)})
        np.testing.assert_array_equal(np.asarray(restored["x"]), np.ones(3))
    finally:
        shutil.rmtree(d)


def test_supervisor_resumes_identically():
    def mk_stream(start):
        def gen():
            i = start
            while True:
                yield jnp.float32(i)
                i += 1
        return gen()

    def step_fn(state, batch):
        return {"w": state["w"] + batch * batch, "n": state["n"] + 1}

    def run(fault_at):
        d = tempfile.mkdtemp()
        try:
            sup = TrainSupervisor(step_fn, CheckpointManager(d, keep_last=3),
                                  SupervisorConfig(ckpt_every=7))
            st, step = sup.run({"w": jnp.float32(0), "n": jnp.int32(0)},
                               mk_stream, 40, fault_at=fault_at)
            return float(st["w"]), int(st["n"]), sup.restarts
        finally:
            shutil.rmtree(d)

    w0, n0, r0 = run(None)
    w1, n1, r1 = run(23)
    assert (w0, n0) == (w1, n1)
    assert (r0, r1) == (0, 1)


def test_supervisor_survives_repeated_faults():
    def mk_stream(start):
        def gen():
            i = start
            while True:
                yield jnp.float32(1.0)
                i += 1
        return gen()

    def step_fn(state, batch):
        return {"w": state["w"] + batch}

    d = tempfile.mkdtemp()
    try:
        sup = TrainSupervisor(step_fn, CheckpointManager(d),
                              SupervisorConfig(ckpt_every=5, max_restarts=5))
        st, step = sup.run({"w": jnp.float32(0)}, mk_stream, 30, fault_at=12)
        # resume + run to completion despite mid-run failure
        assert step == 30 and float(st["w"]) == 30.0
    finally:
        shutil.rmtree(d)


def test_straggler_watchdog():
    cfg = SupervisorConfig(straggler_factor=3.0, max_consecutive_stragglers=2)
    wd = StragglerWatchdog(cfg)
    for i in range(8):
        assert wd.observe(i, 0.1) == "ok"
    assert wd.observe(8, 0.5) == "straggler"
    assert wd.observe(9, 0.5) == "evict"      # second consecutive
    assert len(wd.events) == 2
    assert wd.observe(10, 0.1) == "ok"        # recovers


def test_restore_falls_back_past_torn_latest():
    """A SIGKILL/power-cut can leave the newest checkpoint directory
    complete-looking but truncated; step=None restore must warn, skip it
    and restore the previous step — an explicit step still raises."""
    import pathlib
    import warnings

    d = tempfile.mkdtemp()
    try:
        cm = CheckpointManager(d, keep_last=3)
        tree = {"a": jnp.arange(6.0), "b": jnp.ones((2,), jnp.int32)}
        cm.save(1, tree)
        cm.save(2, jax.tree.map(lambda x: x * 2, tree))
        # truncate the newest payload mid-file: torn zip central directory
        leaves = pathlib.Path(d) / "step_00000002" / "leaves.npz"
        raw = leaves.read_bytes()
        leaves.write_bytes(raw[: len(raw) // 2])

        with pytest.warns(RuntimeWarning, match="torn"):
            restored, step = cm.restore(tree)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        # trusting an explicit step surfaces the damage loudly
        with pytest.raises(Exception):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                cm.restore(tree, step=2)
    finally:
        shutil.rmtree(d)


def test_restore_raises_when_no_readable_checkpoint():
    import pathlib

    d = tempfile.mkdtemp()
    try:
        cm = CheckpointManager(d, keep_last=3)
        cm.save(1, {"x": jnp.zeros(2)})
        leaves = pathlib.Path(d) / "step_00000001" / "leaves.npz"
        leaves.write_bytes(b"\x00" * 8)
        import warnings
        with pytest.raises(FileNotFoundError, match="no readable"):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                cm.restore({"x": jnp.zeros(2)})
    finally:
        shutil.rmtree(d)
