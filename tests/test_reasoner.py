"""HDC graph reasoner: binding composition + top-k/margin gating."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hdc, reasoner
from repro.core.item_memory import random_item_memory
from repro.core.types import TorrConfig

CFG = TorrConfig(D=2048, B=8, M=32, n_relations=8, max_hops=3)


def test_compose_path_matches_manual_binding():
    g = reasoner.init_task_graph(jax.random.PRNGKey(0), CFG, n_tasks=3)
    path = jnp.array([2, 5, -1])
    out = reasoner.compose_path(g, 1, path)
    want = g.text_hv[1].astype(jnp.int32) * g.relations[2].astype(jnp.int32) \
        * g.relations[5].astype(jnp.int32)
    assert (out == want.astype(jnp.int8)).all()


def test_compose_path_padding_is_identity():
    g = reasoner.init_task_graph(jax.random.PRNGKey(0), CFG, n_tasks=2)
    empty = reasoner.compose_path(g, 0, jnp.array([-1, -1, -1]))
    assert (empty == g.text_hv[0]).all()


def test_unbinding_retrieves_task():
    """g_P (*) r = t (binding is self-inverse): the graph is queryable."""
    g = reasoner.init_task_graph(jax.random.PRNGKey(1), CFG, n_tasks=2)
    gp = reasoner.compose_path(g, 0, jnp.array([3, -1, -1]))
    recovered = hdc.bind(gp, g.relations[3])
    assert (recovered == g.text_hv[0]).all()


def test_gating_reuses_cached_output():
    im = random_item_memory(jax.random.PRNGKey(2), CFG)
    scores = jax.random.normal(jax.random.PRNGKey(3), (CFG.M,))
    w = jnp.ones((CFG.M,)) * 2.0
    key, margin = reasoner.topk_key_margin(scores, CFG)
    cached = jnp.full((CFG.M,), 7.0)
    # matching key+margin -> cached output, reasoner gated
    out, active, *_ = reasoner.gate_and_apply(scores, w, cached, key, margin, CFG)
    assert not bool(active)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(cached))
    # mismatched key -> recompute s * w
    out2, active2, *_ = reasoner.gate_and_apply(
        scores, w, cached, jnp.zeros_like(key) - 5, margin, CFG)
    assert bool(active2)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(scores * w),
                               rtol=1e-6)


def test_precomputed_weights_shape():
    g = reasoner.init_task_graph(jax.random.PRNGKey(4), CFG, n_tasks=4)
    im = random_item_memory(jax.random.PRNGKey(5), CFG)
    paths = jnp.array([[0, -1, -1], [1, 2, -1], [3, 4, 5], [-1, -1, -1]])
    w = reasoner.precompute_weights(g, im, CFG, paths)
    assert w.shape == (4, CFG.M)
    assert jnp.all(jnp.abs(w) <= 1.0)
