"""Per-window causal tracing: Tracer/scopes, engine threading, export.

Covers the ISSUE 8 tentpole: Tracer mint/complete semantics (monotone
seq, bounded ring, drop accounting), trace_scope + span stamping
(including the populate-during-scope pattern the dispatcher relies on
and the empty-scope early-out the overhead gate relies on), a traced
sync run (every window completed with resolved plan/lowering), the
acceptance-criteria async run — every admitted window appears exactly
once in the flight records' ``"trace"`` entries with monotone phase
ordering, dispatcher→collector flow pairing in the Chrome export, and
per-window plans bit-consistent with ``Governor.plan_log`` — plus the
Chrome trace-event schema itself and ``serve.py`` graceful shutdown
(SIGTERM flushes the artifacts).
"""
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

import jax

from repro.control import Governor, GovernorPolicy
from repro.core.item_memory import random_item_memory
from repro.core.types import FUSED_NAMES
from repro.obs.flight import FlightRecorder
from repro.obs.metrics import MetricsRegistry
from repro.obs.spans import span
from repro.obs.trace import (TRACE_SCHEMA_VERSION, TraceContext, Tracer,
                             now_us, record_span, trace_scope)
from repro.obs.trace_export import chrome_trace, write_chrome_trace
from repro.serving.async_engine import AsyncStreamEngine
from repro.serving.deadline import DeadlinePolicy, DeadlineTracker
from repro.serving.stream_engine import StreamEngine

from test_multistream import CFG, _make_inputs

FLUSH_S = 120


# --- tracer unit semantics ---------------------------------------------------


def test_tracer_mints_monotone_seq_and_counts():
    reg = MetricsRegistry()
    tr = Tracer(metrics=reg)
    ctxs = [tr.mint(f"s{i}", "sync") for i in range(5)]
    assert [c.seq for c in ctxs] == [0, 1, 2, 3, 4]
    assert tr.minted == 5
    assert all(c.arrival_us >= 0 for c in ctxs)
    snap = reg.snapshot()
    assert snap["torr_trace_windows_total"]["series"][0]["value"] == 5
    assert snap["torr_trace_windows_dropped_total"]["series"][0]["value"] == 0


def test_tracer_ring_bounded_and_drop_counted():
    reg = MetricsRegistry()
    tr = Tracer(capacity=3, metrics=reg)
    for i in range(7):
        tr.complete(tr.mint(f"s{i}", "sync"))
    done = tr.completed()
    assert [c.seq for c in done] == [4, 5, 6]           # oldest fell off
    assert tr.dropped == 4
    assert all(c.complete_us is not None for c in done)
    snap = reg.snapshot()
    assert snap["torr_trace_windows_dropped_total"]["series"][0]["value"] == 4
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_trace_context_to_dict_shape():
    ctx = TraceContext(7, "cam0", "async", arrival_us=123.0)
    ctx.slot = 2
    ctx.stamp("host_decide", 200.0, 50.0, thread="torr-dispatch")
    d = ctx.to_dict()
    assert d["v"] == TRACE_SCHEMA_VERSION
    assert d["seq"] == 7 and d["stream"] == "cam0" and d["slot"] == 2
    assert d["engine"] == "async" and d["arrival_us"] == 123.0
    assert d["events"] == [{"phase": "host_decide", "ts_us": 200.0,
                            "dur_us": 50.0, "thread": "torr-dispatch"}]
    json.dumps(d)                                        # JSONL-ready


# --- scope + span stamping ---------------------------------------------------


def test_record_span_noop_without_scope():
    record_span("anything", time.perf_counter(), 1e-3)   # must not raise


def test_trace_scope_stamps_spans_including_late_population():
    tr = Tracer()
    early = tr.mint("a", "sync")
    ctxs = [early]
    with trace_scope(ctxs):
        with span("host_decide", None):
            # the dispatcher pattern: a window admitted *inside* the span
            # still gets stamped, because stamping happens at span exit
            late = tr.mint("b", "sync")
            ctxs.append(late)
        with span("dispatch_enqueue", None):
            pass
    for ctx in (early, late):
        assert [e["phase"] for e in ctx.events] == ["host_decide",
                                                    "dispatch_enqueue"]
        assert all(e["dur_us"] >= 0 for e in ctx.events)
        assert all(e["thread"] for e in ctx.events)
    # outside the scope spans stamp nothing
    with span("host_observe", None):
        pass
    assert len(early.events) == 2


def test_trace_scope_nesting_innermost_wins():
    inner_ctx, outer_ctx = TraceContext(0, "i", "sync", 0.0), \
        TraceContext(1, "o", "sync", 0.0)
    with trace_scope([outer_ctx]):
        with trace_scope([inner_ctx]):
            with span("work", None):
                pass
        with span("after", None):
            pass
    assert [e["phase"] for e in inner_ctx.events] == ["work"]
    assert [e["phase"] for e in outer_ctx.events] == ["after"]


# --- sync engine integration -------------------------------------------------


def _submit_all(eng, task_w, steps, S):
    futs = []
    for s in range(S):
        eng.admit(f"cam{s}", task_w[s])
        for q, valid, boxes, _qd in steps:
            futs.append(eng.submit(f"cam{s}", q[s], valid[s], boxes[s]))
    return futs


def test_sync_engine_traced_run_completes_every_window():
    cfg = CFG
    S, T = 3, 4
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    reg, fl, tr = MetricsRegistry(), FlightRecorder(), Tracer()
    eng = StreamEngine(cfg, im, n_slots=S, metrics=reg, flight=fl, tracer=tr)
    for s in range(S):
        eng.admit(f"cam{s}", task_w[s])
        for q, valid, boxes, _qd in steps:
            eng.submit(f"cam{s}", q[s], valid[s], boxes[s])
    eng.drain()
    eng.flush_telemetry()   # fold the double-buffered newest step too
    assert tr.minted == S * T
    done = tr.completed()
    assert len(done) == S * T
    for ctx in done:
        assert ctx.engine == "sync" and ctx.decision == "admit"
        assert ctx.plan is not None and ctx.lowering is not None
        assert ctx.lowering["fused"] is not None
        assert ctx.complete_us is not None
        assert {e["phase"] for e in ctx.events} >= {"host_assemble",
                                                    "dispatch_enqueue"}
    recs = fl.records()
    assert len(recs) == T
    for rec in recs:
        assert len(rec["trace"]) == S
        assert rec["ts_us"] >= 0 and rec["queue_depth"] >= 0
        for w in rec["trace"]:
            assert w["step"] == rec["step"]
    # sync engine is single-threaded: no cross-thread flow arrows
    doc = chrome_trace(recs)
    assert not [e for e in doc["traceEvents"] if e["ph"] in ("s", "f")]


def test_untraced_flight_records_carry_no_trace_keys():
    """Without a tracer the record dicts keep their PR 7 shape exactly
    (the JSONL round-trip golden test depends on it)."""
    cfg = CFG
    S, T = 2, 2
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    fl = FlightRecorder()
    eng = StreamEngine(cfg, im, n_slots=S, flight=fl)
    for s in range(S):
        eng.admit(f"cam{s}", task_w[s])
        for q, valid, boxes, _qd in steps:
            eng.submit(f"cam{s}", q[s], valid[s], boxes[s])
    eng.drain()
    for rec in fl.records():
        assert "trace" not in rec
        assert "ts_us" not in rec and "queue_depth" not in rec


# --- async acceptance: exactly-once, ordering, flows, plan consistency -------


@pytest.fixture(scope="module")
def traced_governed_run():
    """One governed 3-stream async run with tracer + flight + governor."""
    cfg = CFG
    S, T = 3, 6
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)
    reg = MetricsRegistry()
    fl, tr = FlightRecorder(), Tracer(metrics=reg)
    tracker = DeadlineTracker(
        DeadlinePolicy(budget_s=30.0, escalate_margin_s=15.0,
                       allow_shed=False), metrics=reg)
    gov = Governor(cfg, GovernorPolicy(budget_s=30.0), metrics=reg)
    with AsyncStreamEngine(cfg, im, n_slots=S, tracker=tracker, governor=gov,
                           paused=True, metrics=reg, flight=fl,
                           tracer=tr) as eng:
        futs = _submit_all(eng, task_w, steps, S)
        eng.start()
        eng.flush(timeout=FLUSH_S)
        for f in futs:
            f.result(timeout=10)
    return {"S": S, "T": T, "recs": fl.records(), "gov": gov, "tracer": tr}


def test_async_every_window_traced_exactly_once(traced_governed_run):
    r = traced_governed_run
    seqs = [w["seq"] for rec in r["recs"] for w in rec["trace"]]
    assert len(seqs) == len(set(seqs)) == r["S"] * r["T"]
    assert sorted(seqs) == list(range(r["S"] * r["T"]))
    assert r["tracer"].minted == r["S"] * r["T"]
    assert len(r["tracer"].completed()) == r["S"] * r["T"]


def test_async_phase_ordering_and_threads(traced_governed_run):
    order = {"host_decide": 0, "dispatch_enqueue": 1, "device_step": 2,
             "collector_drain": 3}
    for rec in traced_governed_run["recs"]:
        for w in rec["trace"]:
            evs = w["events"]
            phases = [e["phase"] for e in evs]
            assert {"host_decide", "dispatch_enqueue", "device_step",
                    "collector_drain"} <= set(phases)
            # monotone: both by timestamp and by causal phase rank
            ranked = sorted(evs, key=lambda e: e["ts_us"])
            assert [order[e["phase"]] for e in ranked] == \
                sorted(order[p] for p in phases)
            by_phase = {e["phase"]: e["thread"] for e in evs}
            assert by_phase["host_decide"] == "torr-dispatch"
            assert by_phase["dispatch_enqueue"] == "torr-dispatch"
            assert by_phase["device_step"] == "torr-collect"
            assert by_phase["collector_drain"] == "torr-collect"
            assert w["arrival_us"] <= ranked[0]["ts_us"]
            assert w["complete_us"] >= ranked[-1]["ts_us"]


def test_async_plans_bit_consistent_with_governor_log(traced_governed_run):
    r = traced_governed_run
    gov, recs = r["gov"], r["recs"]
    assert len(recs) == len(gov.plan_log)
    for rec in recs:
        banks, planes, level = gov.plan_log[rec["step"]]
        for w in rec["trace"]:
            assert (w["plan"]["banks"], w["plan"]["planes"],
                    w["plan"]["level"]) == (banks, planes, level)
            assert w["decision"] in ("admit", "escalate")
            assert w["lowering"]["fused"] in FUSED_NAMES


def test_chrome_trace_schema_and_flow_pairing(traced_governed_run):
    recs = traced_governed_run["recs"]
    doc = chrome_trace(recs)
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    for e in evs:
        assert e["ph"] in ("M", "X", "s", "f", "C")
        assert "pid" in e
        if e["ph"] in ("X", "s", "f"):
            assert e["ts"] >= 0 and "tid" in e
        if e["ph"] == "X":
            assert e["dur"] >= 0
    # thread metadata names both engine threads + the virtual queue row
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"admission_queue", "torr-dispatch", "torr-collect"} <= names
    # flow arrows: one s/f pair per window, dispatcher tid != collector tid
    starts = {e["id"]: e for e in evs if e["ph"] == "s"}
    finishes = {e["id"]: e for e in evs if e["ph"] == "f"}
    n_windows = sum(len(rec["trace"]) for rec in recs)
    assert len(starts) == len(finishes) == n_windows
    assert set(starts) == set(finishes)
    for seq, s_ev in starts.items():
        f_ev = finishes[seq]
        assert f_ev["bp"] == "e"
        assert s_ev["tid"] != f_ev["tid"]
        assert s_ev["ts"] <= f_ev["ts"]
    # every traced window phase appears exactly once as an X event
    x_names = [e["name"] for e in evs if e["ph"] == "X"]
    for phase in ("host_decide", "dispatch_enqueue", "device_step",
                  "collector_drain", "queue_wait"):
        assert x_names.count(phase) == n_windows
    # counters present for the governed run
    assert {e["name"] for e in evs if e["ph"] == "C"} == {
        "plan_level", "energy_ewma_mj", "queue_depth"}


def test_write_chrome_trace_round_trips(traced_governed_run, tmp_path):
    recs = traced_governed_run["recs"]
    path = tmp_path / "trace.json"
    n = write_chrome_trace(recs, str(path))
    doc = json.loads(path.read_text())
    assert len(doc["traceEvents"]) == n > 0
    assert doc["otherData"]["producer"] == "repro.obs.trace_export"


def test_chrome_trace_tolerates_untraced_and_slo_records():
    recs = [
        {"v": 1, "step": 0, "n_windows": 2},             # untraced step
        {"v": 1, "step": 1, "slo": {"level": 1}},        # SLO event record
        {"v": 1, "step": 2, "ts_us": 10.0, "queue_depth": 3,
         "governor": {"level": 1, "energy_ewma_mj": 2.5}, "trace": []},
    ]
    doc = chrome_trace(recs)
    counters = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    assert {c["name"] for c in counters} == {"plan_level", "energy_ewma_mj",
                                             "queue_depth"}
    assert all(c["ts"] == 10.0 for c in counters)


# --- serve.py graceful shutdown ---------------------------------------------


@pytest.mark.slow
def test_serve_sigterm_flushes_artifacts(tmp_path):
    """SIGTERM mid-serve exits 0 and still writes every artifact."""
    m_json = tmp_path / "m.json"
    f_jsonl = tmp_path / "f.jsonl"
    t_json = tmp_path / "t.json"
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    # enough streams x frames that the run cannot finish before the signal
    proc = subprocess.Popen(
        [sys.executable, "-u", "-m", "repro.launch.serve",
         "--torr-streams", "4", "--torr-frames", "600", "--async",
         "--governor", "--torr-fused", "auto",
         "--metrics-json", str(m_json), "--flight-jsonl", str(f_jsonl),
         "--trace-json", str(t_json)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    try:
        deadline = time.time() + 300
        armed = False
        for line in proc.stdout:
            if "SIGINT/SIGTERM flushes artifacts" in line:
                armed = True
                break
            assert time.time() < deadline, "serve never armed its handlers"
        assert armed, "serve exited before arming signal handlers"
        proc.send_signal(signal.SIGTERM)
        out = proc.stdout.read()
        rc = proc.wait(timeout=120)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()
    assert rc == 0, f"serve exited {rc}:\n{out}"
    assert "interrupted" in out
    doc = json.loads(m_json.read_text())
    assert doc["format"] == "torr-metrics-snapshot-v1"
    assert f_jsonl.exists()
    trace_doc = json.loads(t_json.read_text())
    assert "traceEvents" in trace_doc
