"""Multi-stream batched window engine == S independent single-stream runs.

The tentpole invariant (ISSUE 1): ``torr_multi_stream_step`` (both the vmap
and the lax.map lowering) and the ``StreamEngine`` scheduler are *exact*
reformulations of ``torr_window_step`` — scores, argmax and the full path
telemetry agree bit-for-bit per stream, including per-stream load gating
(each stream sees its own N and queue depth, hence its own H and D').
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import hdc, pipeline, types
from repro.core.item_memory import random_item_memory
from repro.core.types import TorrConfig
from repro.kernels import ops
from repro.serving.stream_engine import StreamEngine

CFG = TorrConfig(D=1024, B=8, M=32, K=4, N_max=8, delta_budget=128,
                 feat_dim=64)

TELEM_FIELDS = ("path", "delta_count", "banks", "rho", "n_valid",
                "reasoner_active")


def _make_inputs(cfg, S, T, seed=0):
    """Per-stream temporally coherent windows with varied load: stream s
    flips a few dims per step and draws its own valid counts / queue
    depths, so streams land in different (H, D') regimes."""
    rng = np.random.default_rng(seed)
    base = np.array(hdc.random_hv(jax.random.PRNGKey(seed), (S, cfg.N_max, cfg.D)))
    steps = []
    for _ in range(T):
        flips = rng.integers(0, cfg.D, (S, cfg.N_max, 16))
        for s in range(S):
            for n in range(cfg.N_max):
                base[s, n, flips[s, n]] *= -1
        q = np.asarray(jax.vmap(hdc.pack_bits)(jnp.asarray(base)))
        valid = rng.random((S, cfg.N_max)) < rng.uniform(0.3, 1.0, (S, 1))
        boxes = rng.random((S, cfg.N_max, 4)).astype(np.float32)
        qd = rng.integers(0, 2 * cfg.q_hi, (S,)).astype(np.int32)
        steps.append((q, valid, boxes, qd))
    return steps


@pytest.mark.parametrize("S", [1, 4, 16])
@pytest.mark.parametrize("serial", [False, True])
def test_multi_stream_step_matches_sequential(S, serial):
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M))
    steps = _make_inputs(cfg, S, T=4)

    mstate = pipeline.init_multi_stream_state(cfg, task_w)
    sstates = [pipeline.init_state(cfg, task_w[s]) for s in range(S)]
    mstep = jax.jit(pipeline.torr_multi_stream_step,
                    static_argnames=("cfg", "serial"))
    sstep = jax.jit(pipeline.torr_window_step, static_argnames="cfg")

    for t, (q, valid, boxes, qd) in enumerate(steps):
        mstate, mout, mtel = mstep(
            mstate, im, jnp.asarray(q), jnp.asarray(valid),
            jnp.asarray(boxes), jnp.asarray(qd), cfg, serial=serial)
        for s in range(S):
            sstates[s], sout, stel = sstep(
                sstates[s], im, jnp.asarray(q[s]), jnp.asarray(valid[s]),
                jnp.asarray(boxes[s]), jnp.int32(qd[s]), cfg)
            assert np.array_equal(np.asarray(mout.scores[s]),
                                  np.asarray(sout.scores)), (t, s)
            assert np.array_equal(np.asarray(mout.best[s]),
                                  np.asarray(sout.best)), (t, s)
            assert np.array_equal(np.asarray(mout.boxes[s]),
                                  np.asarray(sout.boxes)), (t, s)
            for f in TELEM_FIELDS:
                assert np.array_equal(np.asarray(getattr(mtel, f)[s]),
                                      np.asarray(getattr(stel, f))), (t, s, f)


@pytest.mark.parametrize("serial", [False, True])
def test_stream_engine_matches_sequential(serial):
    """The scheduler (admit/submit/step with pad slots and real backlog
    depths) reproduces sequential per-stream runs exactly."""
    cfg = CFG
    S, T = 3, 5
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (S, cfg.M)))
    steps = _make_inputs(cfg, S, T)

    # engine with more slots than streams => some lanes always pad
    eng = StreamEngine(cfg, im, n_slots=S + 2, serial=serial)
    for s in range(S):
        eng.admit(f"cam{s}", task_w[s])
        for q, valid, boxes, _ in steps:
            eng.submit(f"cam{s}", q[s], valid[s], boxes[s])
    res = eng.drain()
    assert eng.stats.windows == S * T
    assert eng.stats.pad_slots == 2 * T

    sstep = jax.jit(pipeline.torr_window_step, static_argnames="cfg")
    for s in range(S):
        st = pipeline.init_state(cfg, jnp.asarray(task_w[s]))
        for t, (q, valid, boxes, _) in enumerate(steps):
            # engine queue depth = remaining backlog after the pop
            st, out, tel = sstep(st, im, jnp.asarray(q[s]),
                                 jnp.asarray(valid[s]), jnp.asarray(boxes[s]),
                                 jnp.int32(T - t - 1), cfg)
            eout, etel = res[f"cam{s}"][t]
            assert np.array_equal(np.asarray(eout.scores),
                                  np.asarray(out.scores)), (s, t)
            for f in TELEM_FIELDS:
                assert np.array_equal(np.asarray(getattr(etel, f)),
                                      np.asarray(getattr(tel, f))), (s, t, f)


def test_engine_admit_retire_isolation():
    """A slot reused by a new stream must not see the old stream's cache."""
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (cfg.M,)))
    q = np.asarray(jax.vmap(hdc.pack_bits)(
        hdc.random_hv(jax.random.PRNGKey(2), (cfg.N_max, cfg.D))))
    # fewer valid proposals than the cache depth K, else the window thrashes
    # its own cache and the second pass can never reuse
    valid = np.arange(cfg.N_max) < cfg.K - 1
    boxes = np.zeros((cfg.N_max, 4), np.float32)

    eng = StreamEngine(cfg, im, n_slots=1)
    slot_a = eng.admit("a", task_w)
    eng.submit("a", q, valid, boxes)
    eng.submit("a", q, valid, boxes)
    res = eng.drain()
    # warm cache: second identical window reuses (no full path anywhere)
    assert not (np.asarray(res["a"][1][1].path) == 2).any()
    eng.retire("a")

    slot_b = eng.admit("b", task_w)
    assert slot_b == slot_a  # same physical slot...
    eng.submit("b", q, valid, boxes)
    (out_b, tel_b), = eng.drain()["b"]
    # ...but a cold cache: every valid proposal takes the full path
    assert (np.asarray(tel_b.path)[valid] == 2).all()


def test_retire_drops_unpopped_backlog():
    """A retired stream's queued windows must die with it: the recycled
    slot serves only the new stream's windows (no cross-stream backlog
    leak), and admission asserts the queue came back empty."""
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    task_w = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (cfg.M,)))
    q = np.asarray(jax.vmap(hdc.pack_bits)(
        hdc.random_hv(jax.random.PRNGKey(2), (cfg.N_max, cfg.D))))
    valid = np.ones((cfg.N_max,), bool)
    boxes = np.zeros((cfg.N_max, 4), np.float32)

    eng = StreamEngine(cfg, im, n_slots=1)
    eng.admit("a", task_w)
    for _ in range(3):
        eng.submit("a", q, valid, boxes)
    eng.retire("a")                     # 3 windows still queued
    assert eng.stats.dropped == 3
    assert not eng.busy

    eng.admit("b", task_w)              # asserts the recycled queue is empty
    eng.submit("b", q, valid, boxes)
    res = eng.drain()
    assert list(res) == ["b"] and len(res["b"]) == 1
    assert eng.stats.windows == 1       # none of a's backlog was served

    # a leaked backlog (simulated) trips the clean re-admission assertion
    eng.retire("b")
    eng._pending[0].append((q, valid, boxes))
    with pytest.raises(AssertionError, match="leaked"):
        eng.admit("c", task_w)


def test_engine_slot_exhaustion_and_double_admit():
    cfg = CFG
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    w = np.zeros((cfg.M,), np.float32)
    eng = StreamEngine(cfg, im, n_slots=1)
    eng.admit("a", w)
    with pytest.raises(ValueError):
        eng.admit("a", w)
    with pytest.raises(RuntimeError):
        eng.admit("b", w)
    eng.retire("a")
    eng.admit("b", w)  # slot recycled


def test_ops_cache_nearest_matches_core():
    """The kernel-backed batched PSU lookup agrees with the in-pipeline
    functional `query_cache.nearest` for every query."""
    from repro.core import query_cache

    cfg = TorrConfig(D=2048, B=8, M=16, K=8, delta_budget=256)
    cache = query_cache.init_cache(cfg)
    for i in range(5):
        qe = hdc.pack_bits(hdc.random_hv(jax.random.PRNGKey(10 + i), (cfg.D,)))
        cache = query_cache.write_entry(
            cache, jnp.int32(i), packed=qe,
            acc=jnp.zeros((cfg.M,), jnp.int32),
            acc_tag=types.plan_tag(8, cfg.bit_planes),
            out=jnp.zeros((cfg.M,), jnp.float32),
            topk_key=jnp.zeros((cfg.top_k,), jnp.int32), margin=jnp.float32(0))
    qs = jax.vmap(hdc.pack_bits)(hdc.random_hv(jax.random.PRNGKey(99), (6, cfg.D)))
    for banks in (1, 4, 8):
        idx, rho, ham = ops.cache_nearest(
            qs, cache.packed, cache.valid,
            banks=banks, bank_words=cfg.bank_words)
        for n in range(qs.shape[0]):
            i1, r1, h1 = query_cache.nearest(cache, qs[n], cfg, banks)
            assert int(idx[n]) == int(i1)
            assert float(rho[n]) == float(r1)
            assert int(ham[n]) == int(h1)
