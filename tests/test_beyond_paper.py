"""Beyond-paper features: MXU aligner, online reasoner weights, int8 serving,
EP MoE equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aligner, hdc, reasoner
from repro.core.item_memory import dim_mask, random_item_memory, word_mask
from repro.core.types import TorrConfig

CFG = TorrConfig(D=2048, B=8, M=64, n_relations=8)


def test_mxu_aligner_matches_popcount():
    im = random_item_memory(jax.random.PRNGKey(0), CFG)
    q = hdc.random_hv(jax.random.PRNGKey(1), (4, CFG.D))
    qp = hdc.pack_bits(q)
    for banks in (2, 8):
        wm = word_mask(CFG, banks)
        dm = dim_mask(CFG, banks)
        pop = jnp.stack([aligner.full_dot(qp[i], im, wm) for i in range(4)])
        mxu = aligner.full_dot_mxu(q, im, dm)
        np.testing.assert_array_equal(np.asarray(pop), np.asarray(mxu))


def test_online_weights_match_precomputed():
    g = reasoner.init_task_graph(jax.random.PRNGKey(2), CFG, n_tasks=3)
    im = random_item_memory(jax.random.PRNGKey(3), CFG)
    paths = jnp.array([[1, -1, -1], [0, 2, -1], [3, 4, 5]])
    pre = reasoner.precompute_weights(g, im, CFG, paths)
    for t in range(3):
        online = reasoner.online_weights(g, im, CFG, jnp.int32(t), paths[t],
                                         CFG.B)
        np.testing.assert_allclose(np.asarray(online), np.asarray(pre[t]),
                                   atol=1e-6)


def test_int8_serving_decode_close_to_bf16():
    from repro.configs import get_smoke
    from repro.models import transformer as tf
    cfg0 = dataclasses.replace(get_smoke("qwen3-14b"), remat_policy="full")
    cfgq = dataclasses.replace(cfg0, serve_quant="int8")
    params = tf.init_params(jax.random.PRNGKey(0), cfg0)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg0.vocab, (2, 10)), jnp.int32)
    cb = tf.init_cache(cfg0, 2, 16)
    cq = tf.init_cache(cfgq, 2, 16)
    for t in range(10):
        cb, lb = tf.decode_step(params, cb, toks[:, t], cfg0)
        cq, lq = tf.decode_step(params, cq, toks[:, t], cfgq)
    err = float(jnp.max(jnp.abs(jax.nn.softmax(lb) - jax.nn.softmax(lq))))
    assert err < 0.05, err


def test_quant_cache_structure():
    from repro.configs import get_smoke
    from repro.models import transformer as tf
    cfg = dataclasses.replace(get_smoke("deepseek-v3-671b"),
                              serve_quant="int8")
    cache = tf.init_cache(cfg, 2, 32)
    assert cache["ckv"]["q"].dtype == jnp.int8
    assert cache["ckv"]["s"].dtype == jnp.float32
    assert cache["ckv_prefix"]["q"].dtype == jnp.int8


def test_ep_moe_equivalence_subprocess():
    import os
    import subprocess
    import sys
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = ModelConfig(name="t", family="moe", d_model=32, n_experts=8,
                  moe_top_k=2, moe_d_ff=16, n_shared_experts=1,
                  capacity_factor=8.0)
p = moe_mod.init_moe_params(jax.random.PRNGKey(3), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(4), (4, 8, 32))
y0, _ = moe_mod.moe_ffn(p, x, cfg)
xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
def spec(k):
    if k.startswith("w_"): return P("model", None, None)
    if k in ("shared_gate", "shared_up"): return P(None, "model")
    if k == "shared_down": return P("model", None)
    return P()
ps = jax.device_put(p, {k: NamedSharding(mesh, spec(k)) for k in p})
y1, _ = jax.jit(lambda p, x: moe_mod.moe_ffn_ep(p, x, cfg, mesh))(ps, xs)
np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-4)
print("EP_EQ_OK")
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "EP_EQ_OK" in out.stdout
