"""Recurrent cells: chunkwise/parallel forms vs sequential references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import recurrent
from repro.models.config import ModelConfig


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]))
@settings(max_examples=8, deadline=None)
def test_mlstm_chunkwise_exact(seed, chunk):
    B, S, H, dh = 1, 16, 2, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, dh)) for i in range(3))
    ig = jax.random.normal(ks[3], (B, S, H)) * 2
    fg = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) * 2)

    out, _ = recurrent._mlstm_chunk_scan(q, k, v, ig, fg, chunk)
    ref, _ = recurrent._mlstm_chunk_scan(q, k, v, ig, fg, 1)  # per-step exact
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_rglru_parallel_vs_decode():
    cfg = ModelConfig(name="t", family="hybrid", d_model=16, lru_width=24)
    p = recurrent.init_rglru_params(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 12, 16))
    y_par = recurrent.rglru_train(p, x, cfg)
    st_ = recurrent.rglru_init_state(cfg, 2, jnp.float32)
    ys = []
    for t in range(12):
        y, st_ = recurrent.rglru_decode(p, x[:, t:t + 1], st_, cfg)
        ys.append(y[:, 0])
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(jnp.stack(ys, 1)),
                               atol=1e-4)


def test_rglru_prefill_state_continues_decode():
    cfg = ModelConfig(name="t", family="hybrid", d_model=16, lru_width=24)
    p = recurrent.init_rglru_params(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 10, 16))
    y_full = recurrent.rglru_train(p, x, cfg)
    _, st_ = recurrent.rglru_prefill(p, x[:, :7], cfg)
    ys = []
    for t in range(7, 10):
        y, st_ = recurrent.rglru_decode(p, x[:, t:t + 1], st_, cfg)
        ys.append(y[:, 0])
    np.testing.assert_allclose(np.asarray(y_full[:, 7:]),
                               np.asarray(jnp.stack(ys, 1)), atol=1e-4)


def test_mlstm_block_train_vs_decode():
    cfg = ModelConfig(name="t", family="ssm", d_model=32, n_heads=2,
                      mlstm_chunk=8)
    p = recurrent.init_mlstm_params(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 32))
    y_train = recurrent.mlstm_train(p, x, cfg)
    st_ = recurrent.mlstm_init_state(cfg, 2, jnp.float32)
    ys = []
    for t in range(16):
        y, st_ = recurrent.mlstm_decode(p, x[:, t:t + 1], st_, cfg)
        ys.append(y[:, 0])
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(jnp.stack(ys, 1)),
                               atol=1e-3)


def test_slstm_train_vs_decode():
    cfg = ModelConfig(name="t", family="ssm", d_model=32, n_heads=2)
    p = recurrent.init_slstm_params(jax.random.PRNGKey(5), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 16, 32))
    y = recurrent.slstm_train(p, x, cfg)
    st_ = recurrent.slstm_init_state(cfg, 2)
    ys = []
    for t in range(16):
        yt, st_ = recurrent.slstm_decode(p, x[:, t:t + 1], st_, cfg)
        ys.append(yt[:, 0])
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys, 1)),
                               atol=1e-4)


def test_gates_bounded_stability():
    """Exponential gating stays finite over long sequences (stabilizer m)."""
    cfg = ModelConfig(name="t", family="ssm", d_model=16, n_heads=2,
                      mlstm_chunk=16)
    p = recurrent.init_mlstm_params(jax.random.PRNGKey(7), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(8), (1, 256, 16)) * 5.0
    y = recurrent.mlstm_train(p, x, cfg)
    assert jnp.isfinite(y).all()
