"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hdc
from repro.kernels import ops, ref


@pytest.mark.parametrize("D,M,N", [(1024, 8, 1), (4096, 128, 8),
                                   (8192, 64, 4), (2048, 256, 2)])
def test_packed_similarity_shapes(D, M, N):
    hv = hdc.random_hv(jax.random.PRNGKey(0), (M, D))
    q = hdc.random_hv(jax.random.PRNGKey(1), (N, D))
    imp, qp = hdc.pack_bits(hv), hdc.pack_bits(q)
    B = 8
    bw = D // B // 32
    for banks in (1, 3, B):
        if (banks * bw) % 128 and banks != B:
            continue
        acc, cos = ops.packed_similarity(qp, imp, banks=banks, bank_words=bw)
        d_eff = banks * bw * 32
        want = jnp.einsum("nd,md->nm", q[:, :d_eff].astype(jnp.int32),
                          hv[:, :d_eff].astype(jnp.int32))
        assert (acc == want).all(), (D, M, N, banks)


@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 128, 384]),
       st.sampled_from([8, 64, 96]))
@settings(max_examples=10, deadline=None)
def test_delta_update_property(seed, M, budget):
    D = 2048
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    hv = hdc.random_hv(ks[0], (M, D))
    dmaj = jnp.transpose(hv)
    acc = jax.random.randint(ks[1], (M,), -1000, 1000, jnp.int32)
    idx = jax.random.randint(ks[2], (budget,), 0, D, jnp.int32)
    w = jnp.where(jax.random.bernoulli(ks[3], 0.5, (budget,)), 2, -2)
    w = w.astype(jnp.int32).at[budget // 2:].set(0)  # padding
    out = ops.delta_update(acc, dmaj, idx, w)
    want = ref.delta_update_ref(acc, dmaj, idx, w)
    assert (out == want).all()


@pytest.mark.parametrize("N,d,D", [(8, 64, 512), (16, 512, 4096),
                                   (8, 100, 1024)])
def test_sign_project_shapes(N, d, D):
    z = jax.random.normal(jax.random.PRNGKey(0), (N, d))
    R = jax.random.normal(jax.random.PRNGKey(1), (D, d))
    assert (ops.sign_project(z, R) == ref.sign_project_ref(z, R)).all()


def test_fallback_on_ragged_shapes():
    """Off-tile shapes must transparently use the oracle."""
    z = jax.random.normal(jax.random.PRNGKey(0), (3, 33))   # N=3 not /8
    R = jax.random.normal(jax.random.PRNGKey(1), (100, 33))  # D=100 not /128
    assert (ops.sign_project(z, R) == ref.sign_project_ref(z, R)).all()

    hv = hdc.random_hv(jax.random.PRNGKey(2), (7, 64))       # M=7 not /8
    q = hdc.random_hv(jax.random.PRNGKey(3), (2, 64))
    acc, _ = ops.packed_similarity(hdc.pack_bits(q), hdc.pack_bits(hv),
                                   banks=1, bank_words=2)
    want = jnp.einsum("nd,md->nm", q.astype(jnp.int32), hv.astype(jnp.int32))
    assert (acc == want).all()


def test_delta_equals_full_rescan():
    """Integration: accumulator + delta corrections == fresh full scan."""
    D, M, budget = 2048, 64, 256
    hv = hdc.random_hv(jax.random.PRNGKey(0), (M, D))
    q0 = hdc.random_hv(jax.random.PRNGKey(1), (D,))
    flips = jax.random.choice(jax.random.PRNGKey(2), D, (100,), replace=False)
    q1 = q0.at[flips].multiply(-1)

    acc0, _ = ops.packed_similarity(hdc.pack_bits(q0)[None], hdc.pack_bits(hv),
                                    banks=8, bank_words=D // 8 // 32)
    from repro.core import aligner
    from repro.core.item_memory import build_item_memory, word_mask
    from repro.core.types import TorrConfig
    cfg = TorrConfig(D=D, B=8, M=M, delta_budget=budget)
    im = build_item_memory(hv)
    idx, w, cnt = aligner.delta_indices(
        hdc.pack_bits(q1), hdc.pack_bits(q0), word_mask(cfg, 8), budget, D)
    assert int(cnt) == 100
    acc1 = ops.delta_update(acc0[0], im.dmajor, idx, w)
    want, _ = ops.packed_similarity(hdc.pack_bits(q1)[None], hdc.pack_bits(hv),
                                    banks=8, bank_words=D // 8 // 32)
    assert (acc1 == want[0]).all()
