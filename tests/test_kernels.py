"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hdc
from repro.kernels import fused_window, ops, ref


@pytest.mark.parametrize("D,M,N", [(1024, 8, 1), (4096, 128, 8),
                                   (8192, 64, 4), (2048, 256, 2)])
def test_packed_similarity_shapes(D, M, N):
    hv = hdc.random_hv(jax.random.PRNGKey(0), (M, D))
    q = hdc.random_hv(jax.random.PRNGKey(1), (N, D))
    imp, qp = hdc.pack_bits(hv), hdc.pack_bits(q)
    B = 8
    bw = D // B // 32
    for banks in (1, 3, B):
        if (banks * bw) % 128 and banks != B:
            continue
        acc, cos = ops.packed_similarity(qp, imp, banks=banks, bank_words=bw)
        d_eff = banks * bw * 32
        want = jnp.einsum("nd,md->nm", q[:, :d_eff].astype(jnp.int32),
                          hv[:, :d_eff].astype(jnp.int32))
        assert (acc == want).all(), (D, M, N, banks)


@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 128, 384]),
       st.sampled_from([8, 64, 96]))
@settings(max_examples=10, deadline=None)
def test_delta_update_property(seed, M, budget):
    D = 2048
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    hv = hdc.random_hv(ks[0], (M, D))
    dmaj = jnp.transpose(hv)
    acc = jax.random.randint(ks[1], (M,), -1000, 1000, jnp.int32)
    idx = jax.random.randint(ks[2], (budget,), 0, D, jnp.int32)
    w = jnp.where(jax.random.bernoulli(ks[3], 0.5, (budget,)), 2, -2)
    w = w.astype(jnp.int32).at[budget // 2:].set(0)  # padding
    out = ops.delta_update(acc, dmaj, idx, w)
    want = ref.delta_update_ref(acc, dmaj, idx, w)
    assert (out == want).all()


@pytest.mark.parametrize("N,d,D", [(8, 64, 512), (16, 512, 4096),
                                   (8, 100, 1024)])
def test_sign_project_shapes(N, d, D):
    z = jax.random.normal(jax.random.PRNGKey(0), (N, d))
    R = jax.random.normal(jax.random.PRNGKey(1), (D, d))
    assert (ops.sign_project(z, R) == ref.sign_project_ref(z, R)).all()


def test_fallback_on_ragged_shapes():
    """Off-tile shapes must transparently use the oracle."""
    z = jax.random.normal(jax.random.PRNGKey(0), (3, 33))   # N=3 not /8
    R = jax.random.normal(jax.random.PRNGKey(1), (100, 33))  # D=100 not /128
    assert (ops.sign_project(z, R) == ref.sign_project_ref(z, R)).all()

    hv = hdc.random_hv(jax.random.PRNGKey(2), (7, 64))       # M=7 not /8
    q = hdc.random_hv(jax.random.PRNGKey(3), (2, 64))
    acc, _ = ops.packed_similarity(hdc.pack_bits(q), hdc.pack_bits(hv),
                                   banks=1, bank_words=2)
    want = jnp.einsum("nd,md->nm", q.astype(jnp.int32), hv.astype(jnp.int32))
    assert (acc == want).all()


# --- fused window-step kernel family ---------------------------------------

@pytest.mark.parametrize("D,M,N", [(1024, 8, 1), (2048, 64, 16),
                                   (4096, 128, 8), (2048, 256, 3)])
def test_fused_scores_grid(D, M, N):
    """Interpret-mode kernel grid: acc, argmax and top-2 readout are all
    bit-identical to the oracle (ties: lowest index, lax.top_k order)."""
    hv = hdc.random_hv(jax.random.PRNGKey(0), (M, D))
    q = hdc.random_hv(jax.random.PRNGKey(1), (N, D))
    imp, qp = hdc.pack_bits(hv), hdc.pack_bits(q)
    acc, best, top2 = fused_window.fused_scores(qp, imp, d_eff=D,
                                                interpret=True)
    w_acc, w_best, w_top2 = ref.fused_scores_ref(qp, imp, d_eff=D)
    assert np.array_equal(np.asarray(acc), np.asarray(w_acc))
    assert np.array_equal(np.asarray(best), np.asarray(w_best))
    assert np.array_equal(np.asarray(top2), np.asarray(w_top2))


def test_fused_scores_argmax_tie_breaking():
    """Duplicated item-memory rows force exact ties; the fused readout must
    keep jnp.argmax's lowest-index winner."""
    D, N = 1024, 8
    hv0 = hdc.random_hv(jax.random.PRNGKey(0), (8, D))
    hv = jnp.concatenate([hv0, hv0], axis=0)            # every row twice
    q = hdc.random_hv(jax.random.PRNGKey(1), (N, D))
    imp, qp = hdc.pack_bits(hv), hdc.pack_bits(q)
    acc, best, top2 = fused_window.fused_scores(qp, imp, d_eff=D,
                                                interpret=True)
    assert np.array_equal(np.asarray(best),
                          np.asarray(jnp.argmax(acc, -1)))
    assert (np.asarray(best) < 8).all()                 # first copy wins
    assert np.array_equal(np.asarray(top2),
                          np.asarray(jax.lax.top_k(acc, 2)[0]))
    # the duplicated memory makes top-1 == top-2 exactly
    assert (np.asarray(top2)[:, 0] == np.asarray(top2)[:, 1]).all()


@pytest.mark.parametrize("D,M,N,cap", [(1024, 8, 4, 8), (2048, 64, 16, 8),
                                       (2048, 64, 5, 4), (4096, 32, 8, 2)])
def test_bank_prefix_hamming_grid(D, M, N, cap):
    hv = hdc.random_hv(jax.random.PRNGKey(2), (M, D))
    q = hdc.random_hv(jax.random.PRNGKey(3), (N, D))
    imp, qp = hdc.pack_bits(hv), hdc.pack_bits(q)
    out = fused_window.bank_prefix_hamming(qp, imp, cap=cap, interpret=True)
    want = ref.bank_prefix_hamming_ref(qp, imp, cap=cap)
    assert out.shape == (N, M, cap)
    assert np.array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("D,M,N", [(2048, 64, 16), (1024, 24, 5)])
def test_blocked_lowerings_match_kernel(D, M, N):
    """The CPU blocked-jnp lowering == the interpret-mode Pallas grid ==
    the oracle, for both the fused-scores and bank-prefix family members."""
    hv = hdc.random_hv(jax.random.PRNGKey(4), (M, D))
    q = hdc.random_hv(jax.random.PRNGKey(5), (N, D))
    imp, qp = hdc.pack_bits(hv), hdc.pack_bits(q)
    blocked = fused_window._blocked_scores(qp, imp, d_eff=D)
    kern = fused_window.fused_scores(qp, imp, d_eff=D, interpret=True)
    want = ref.fused_scores_ref(qp, imp, d_eff=D)
    for b, k, w in zip(blocked, kern, want):
        assert np.array_equal(np.asarray(b), np.asarray(w))
        assert np.array_equal(np.asarray(k), np.asarray(w))
    bp = fused_window._blocked_prefix(qp, imp, cap=8)
    kp = fused_window.bank_prefix_hamming(qp, imp, cap=8, interpret=True)
    wp = ref.bank_prefix_hamming_ref(qp, imp, cap=8)
    assert np.array_equal(np.asarray(bp), np.asarray(wp))
    assert np.array_equal(np.asarray(kp), np.asarray(wp))


def test_fused_any_ragged_falls_back():
    """M not a multiple of 8 transparently uses the oracle."""
    hv = hdc.random_hv(jax.random.PRNGKey(6), (7, 1024))
    q = hdc.random_hv(jax.random.PRNGKey(7), (3, 1024))
    imp, qp = hdc.pack_bits(hv), hdc.pack_bits(q)
    acc, best, top2 = fused_window.fused_scores_any(qp, imp, d_eff=1024)
    w = ref.fused_scores_ref(qp, imp, d_eff=1024)
    assert np.array_equal(np.asarray(acc), np.asarray(w[0]))
    assert np.array_equal(np.asarray(best), np.asarray(w[1]))
    hp = fused_window.bank_prefix_hamming_any(qp, imp, cap=4)
    assert np.array_equal(np.asarray(hp),
                          np.asarray(ref.bank_prefix_hamming_ref(
                              qp, imp, cap=4)))


@pytest.mark.parametrize("N,d,D", [(8, 64, 512), (16, 512, 4096),
                                   (8, 100, 1024), (3, 33, 100)])
def test_sign_project_pack(N, d, D):
    """Fused encode->pack == pack_bits(sign_project) — kernel where D packs
    to words (D % 32 == 0), oracle fallback elsewhere via ops."""
    z = jax.random.normal(jax.random.PRNGKey(0), (N, d))
    R = jax.random.normal(jax.random.PRNGKey(1), (D, d))
    if D % 32 == 0:
        want = hdc.pack_bits(ref.sign_project_ref(z, R))
        if D % 128 == 0 and N % 8 == 0:
            out = fused_window.sign_project_pack(z, R, interpret=True)
            assert np.array_equal(np.asarray(out), np.asarray(want))
        out2 = ops.encode_packed(z, R)
        assert np.array_equal(np.asarray(out2), np.asarray(want))
    else:
        with pytest.raises(ValueError):
            ref.sign_project_pack_ref(z, R)


def test_fused_similarity_matches_packed_similarity():
    """ops.fused_similarity (acc, cos) == ops.packed_similarity under every
    (banks, planes) plan; best/top2 match the oracle readout."""
    from repro.core.item_memory import random_item_memory
    from repro.core.types import TorrConfig
    cfg = TorrConfig(D=1024, B=8, M=32, K=4, N_max=8, delta_budget=128,
                     feat_dim=64)
    im = random_item_memory(jax.random.PRNGKey(0), cfg)
    qp = hdc.pack_bits(hdc.random_hv(jax.random.PRNGKey(1), (5, cfg.D)))
    for banks, planes in [(8, 4), (8, 2), (4, 1), (2, 2)]:
        acc, cos, best, top2 = ops.fused_similarity(
            qp, im.packed, banks=banks, bank_words=cfg.bank_words,
            planes=planes, plane_total=cfg.bit_planes, pmajor=im.pmajor)
        acc2, cos2 = ops.packed_similarity(
            qp, im.packed, banks=banks, bank_words=cfg.bank_words,
            planes=planes, plane_total=cfg.bit_planes, pmajor=im.pmajor)
        assert np.array_equal(np.asarray(acc), np.asarray(acc2))
        assert np.allclose(np.asarray(cos), np.asarray(cos2))
        assert np.array_equal(np.asarray(best),
                              np.asarray(jnp.argmax(acc, -1)))
        assert np.array_equal(np.asarray(top2),
                              np.asarray(jax.lax.top_k(acc, 2)[0]))


def test_delta_apply_dispatch():
    """fused_window.delta_apply == the oracle in every lowering (kernel via
    explicit interpret, vectorized form via the default CPU dispatch,
    oracle fallback on ragged M)."""
    D, budget = 1024, 64
    for M in (64, 7):
        ks = jax.random.split(jax.random.PRNGKey(M), 4)
        dmaj = jnp.transpose(hdc.random_hv(ks[0], (M, D)))
        acc = jax.random.randint(ks[1], (M,), -500, 500, jnp.int32)
        idx = jax.random.randint(ks[2], (budget,), 0, D, jnp.int32)
        w = jnp.where(jax.random.bernoulli(ks[3], 0.5, (budget,)), 2, -2)
        w = w.astype(jnp.int32).at[budget // 2:].set(0)
        want = ref.delta_update_ref(acc, dmaj, idx, w)
        for interpret in (None, True):
            out = fused_window.delta_apply(acc, dmaj, idx, w,
                                           interpret=interpret)
            assert np.array_equal(np.asarray(out), np.asarray(want)), \
                (M, interpret)


def test_tune_file_precedence(tmp_path, monkeypatch):
    """TORR_TUNE_FILE loads the autotune artifact's block shapes; explicit
    TORR_TQ/TORR_TM still win; a corrupt file is an error."""
    import importlib
    import json as _json
    from repro.kernels import xnor_popcount_sim as xps

    art = tmp_path / "tune.json"
    art.write_text(_json.dumps({"best": {"tq": 4, "tm": 16}}))
    monkeypatch.setenv("TORR_TUNE_FILE", str(art))
    monkeypatch.delenv("TORR_TQ", raising=False)
    monkeypatch.delenv("TORR_TM", raising=False)
    try:
        mod = importlib.reload(xps)
        assert mod.TQ_DEFAULT == 4 and mod.TM_DEFAULT == 16
        monkeypatch.setenv("TORR_TQ", "2")
        mod = importlib.reload(xps)
        assert mod.TQ_DEFAULT == 2 and mod.TM_DEFAULT == 16  # env wins
        art.write_text("not json")
        with pytest.raises(ValueError):
            importlib.reload(xps)
    finally:
        monkeypatch.delenv("TORR_TUNE_FILE", raising=False)
        monkeypatch.delenv("TORR_TQ", raising=False)
        importlib.reload(xps)


def test_delta_equals_full_rescan():
    """Integration: accumulator + delta corrections == fresh full scan."""
    D, M, budget = 2048, 64, 256
    hv = hdc.random_hv(jax.random.PRNGKey(0), (M, D))
    q0 = hdc.random_hv(jax.random.PRNGKey(1), (D,))
    flips = jax.random.choice(jax.random.PRNGKey(2), D, (100,), replace=False)
    q1 = q0.at[flips].multiply(-1)

    acc0, _ = ops.packed_similarity(hdc.pack_bits(q0)[None], hdc.pack_bits(hv),
                                    banks=8, bank_words=D // 8 // 32)
    from repro.core import aligner
    from repro.core.item_memory import build_item_memory, word_mask
    from repro.core.types import TorrConfig
    cfg = TorrConfig(D=D, B=8, M=M, delta_budget=budget)
    im = build_item_memory(hv)
    idx, w, cnt = aligner.delta_indices(
        hdc.pack_bits(q1), hdc.pack_bits(q0), word_mask(cfg, 8), budget, D)
    assert int(cnt) == 100
    acc1 = ops.delta_update(acc0[0], im.dmajor, idx, w)
    want, _ = ops.packed_similarity(hdc.pack_bits(q1)[None], hdc.pack_bits(hv),
                                    banks=8, bank_words=D // 8 // 32)
    assert (acc1 == want[0]).all()
