"""HDC primitive identities (unit + property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hdc


@given(st.integers(0, 2**31 - 1), st.sampled_from([32, 64, 256, 1024]))
@settings(max_examples=20, deadline=None)
def test_pack_unpack_roundtrip(seed, D):
    hv = hdc.random_hv(jax.random.PRNGKey(seed), (3, D))
    assert (hdc.unpack_bits(hdc.pack_bits(hv), D) == hv).all()


@given(st.integers(0, 2**31 - 1), st.sampled_from([32, 128, 512]))
@settings(max_examples=20, deadline=None)
def test_packed_dot_identity(seed, D):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = hdc.random_hv(k1, (D,))
    b = hdc.random_hv(k2, (D,))
    assert int(hdc.dot_packed(hdc.pack_bits(a), hdc.pack_bits(b))) == \
        int(hdc.dot_bipolar(a, b))
    np.testing.assert_allclose(
        float(hdc.cosine_packed(hdc.pack_bits(a), hdc.pack_bits(b))),
        float(hdc.cosine_bipolar(a, b)), rtol=1e-6)


def test_bind_self_inverse():
    a = hdc.random_hv(jax.random.PRNGKey(0), (256,))
    b = hdc.random_hv(jax.random.PRNGKey(1), (256,))
    assert (hdc.bind(hdc.bind(a, b), b) == a).all()


def test_bind_associative_commutative():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a, b, c = (hdc.random_hv(k, (128,)) for k in ks)
    assert (hdc.bind(hdc.bind(a, b), c) == hdc.bind(a, hdc.bind(b, c))).all()
    assert (hdc.bind(a, b) == hdc.bind(b, a)).all()


def test_bundle_majority_preserves_similarity():
    D = 4096
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    hvs = jnp.stack([hdc.random_hv(k, (D,)) for k in ks])
    bundled = hdc.bundle(hvs)
    for i in range(3):
        cos = float(hdc.cosine_bipolar(bundled, hvs[i]))
        assert cos > 0.3, cos  # each component stays recoverable
    other = hdc.random_hv(jax.random.PRNGKey(9), (D,))
    assert abs(float(hdc.cosine_bipolar(bundled, other))) < 0.1


def test_rho_identity_eq5():
    """rho = 1 - 2|Delta|/D (paper Eq. 5)."""
    D = 1024
    a = hdc.random_hv(jax.random.PRNGKey(4), (D,))
    flips = jnp.arange(0, D, 64)
    b = a.at[flips].multiply(-1)
    rho = float(hdc.cosine_bipolar(a, b))
    assert abs(rho - (1 - 2 * len(flips) / D)) < 1e-6
    ham = int(hdc.hamming_packed(hdc.pack_bits(a), hdc.pack_bits(b)))
    assert ham == len(flips)


def test_sign_project_bipolar():
    z = jax.random.normal(jax.random.PRNGKey(5), (4, 64))
    R = jax.random.normal(jax.random.PRNGKey(6), (512, 64))
    q = hdc.sign_project(z, R)
    assert q.dtype == jnp.int8
    assert set(np.unique(np.asarray(q))) <= {-1, 1}
